"""Step builders + abstract input specs for every (arch x shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for model inputs; ``abstract_state`` does the
same for params/optimizer state.  The dry-run lowers
``jax.jit(step, in_shardings=..., out_shardings=...)`` against these — the
same functions the real train/serve drivers execute.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig, RuntimeConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import lm
from repro.optim import adamw, schedule


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def _batch_dims(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, tuple]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio_frames":
        return {"frames": (b, s, cfg.frontend_dim), "labels": (b, s)}
    dims: dict[str, tuple] = {}
    if cfg.frontend == "vision_patches":
        s_text = s - cfg.n_prefix_tokens
        dims["tokens"] = (b, s_text)
        dims["patches"] = (b, cfg.n_prefix_tokens, cfg.frontend_dim)
        dims["labels"] = (b, s_text)
    else:
        dims["tokens"] = (b, s)
        dims["labels"] = (b, s)
    return dims


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStructs for one training/prefill batch."""
    act_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    out = {}
    for name, dims in _batch_dims(cfg, shape).items():
        dt = jnp.int32 if name in ("tokens", "labels") else act_dtype
        out[name] = jax.ShapeDtypeStruct(dims, dt)
    if shape.kind != "train":
        out.pop("labels", None)
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig
                       ) -> tuple[dict, Any]:
    """(tokens_t spec, cache spec tree) for one decode step with a KV/state
    cache sized for ``shape.seq_len``."""
    b = shape.global_batch
    cache_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    cache = jax.eval_shape(
        lambda: lm.init_decode_cache(cfg, b, shape.seq_len, cache_dtype))
    tokens = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    return tokens, cache


@functools.lru_cache(maxsize=None)
def _abstract_params(cfg: ModelConfig):
    """(params ShapeDtypeStruct tree, logical-axes tree), zero allocation.
    The axes tree is static metadata, captured as a side output while
    tracing init under eval_shape."""
    closure: list = []

    def capture(key):
        p, a = lm.init(key, cfg)
        closure.append(a)
        return p

    params = jax.eval_shape(capture, jax.random.PRNGKey(0))
    return params, closure[0]


def abstract_state(cfg: ModelConfig, *, with_opt: bool = True):
    """(params shapes, axes tree, opt-state shapes) with zero allocation."""
    params, axes = _abstract_params(cfg)
    opt = jax.eval_shape(adamw.init, params) if with_opt else None
    return params, axes, opt


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def default_opt_config(total_steps: int = 1000) -> adamw.AdamWConfig:
    return adamw.AdamWConfig(
        lr=schedule.warmup_cosine(3e-4, min(100, total_steps // 10 + 1),
                                  total_steps))


def make_train_step(cfg: ModelConfig, rt: RuntimeConfig,
                    opt_cfg: adamw.AdamWConfig) -> Callable:
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True)(params, batch, cfg, rt)
        params, opt_state, opt_metrics = adamw.update(
            opt_cfg, grads, opt_state, params)
        return params, opt_state, {**metrics, **opt_metrics}
    return train_step


def make_prefill_step(cfg: ModelConfig, rt: RuntimeConfig) -> Callable:
    def prefill_step(params, batch):
        return lm.prefill(params, batch, cfg, rt)
    return prefill_step


def make_decode_step(cfg: ModelConfig, rt: RuntimeConfig) -> Callable:
    def serve_step(params, cache, batch):
        return lm.decode_step(params, cache, batch["tokens"], cfg, rt)
    return serve_step


# ---------------------------------------------------------------------------
# Sharding assembly
# ---------------------------------------------------------------------------

def _maybe_batch_spec(tree, mesh: Mesh) -> Any:
    """Shard the leading batch dim when it divides the data extent(s);
    otherwise replicate (long_500k has global_batch=1)."""
    axes = shd.batch_axes(mesh)
    flat = axes if isinstance(axes, tuple) else (axes,)
    extent = 1
    for a in flat:
        extent *= mesh.shape[a]

    def leaf(x):
        if x.shape and x.shape[0] % extent == 0 and x.shape[0] > 0:
            return P(axes, *([None] * (len(x.shape) - 1)))
        return P(*([None] * len(x.shape)))

    return jax.tree_util.tree_map(leaf, tree)


@dataclasses.dataclass(frozen=True)
class CellLowering:
    """Everything needed to lower one (arch x shape x mesh) cell."""
    step: Callable
    args: tuple                     # abstract operand trees, in order
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()


# ---------------------------------------------------------------------------
# Per-super-block part lowerings (roofline trip-count correction).
# ---------------------------------------------------------------------------

def _drop_layer_dim(tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), tree)


def _drop_layer_spec(tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: P(*tuple(s)[1:]), tree,
        is_leaf=lambda x: isinstance(x, P))


def plan_part_cells(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    rt: RuntimeConfig,
                    rules: shd.ShardingRules = shd.ShardingRules()
                    ) -> list[tuple[str, CellLowering, int]]:
    """Returns [(part_name, lowering, extra_multiplier)] where
    ``corrected_cost = full_cost + sum(extra_multiplier * part_cost)``.
    The extra multiplier is (trip_count - 1): the full lowering already
    counts each scanned body once."""
    rt = resolve_rt(cfg, mesh, rt)
    rt = dataclasses.replace(rt, scan_unroll=True, loss_unroll=True)
    params, axes, _ = abstract_state(cfg, with_opt=False)
    pspecs = shd.repair_specs(
        params, shd.param_specs(axes, rules, mesh), mesh)
    plan = lm.layer_plan(cfg)
    act_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    b = shape.global_batch
    s = 1 if shape.kind == "decode" else shape.seq_len
    x = jax.ShapeDtypeStruct((b, s, cfg.d_model), act_dtype)
    xspec = shd.repair_spec(x.shape, P(shd.batch_axes(mesh), None, None),
                            mesh)
    shared = params.get("shared_attn")
    shared_spec = pspecs.get("shared_attn")
    use_shared = plan.uses_shared_attn

    parts: list[tuple[str, CellLowering, int]] = []

    def add(name: str, step, args, in_sh, mult: int, out_sh=None,
            donate=()):
        if mult > 0:
            parts.append((name, CellLowering(
                step=step, args=args, in_shardings=in_sh,
                out_shardings=out_sh, donate_argnums=donate), mult))

    if shape.kind == "train":
        if use_shared:
            def block_step(bp, sh, xx):
                def f(bp_, sh_, x_):
                    y, aux = lm.superblock_fwd(bp_, sh_, x_, cfg, rt)
                    return (jnp.sum(y.astype(jnp.float32))
                            + aux["router_aux_loss"])
                return jax.grad(f, argnums=(0, 1, 2))(bp, sh, xx)
            args = (_drop_layer_dim(params["blocks"]), shared, x)
            in_sh = (_drop_layer_spec(pspecs["blocks"]), shared_spec, xspec)
        else:
            def block_step(bp, xx):
                def f(bp_, x_):
                    y, aux = lm.superblock_fwd(bp_, None, x_, cfg, rt)
                    return (jnp.sum(y.astype(jnp.float32))
                            + aux["router_aux_loss"])
                return jax.grad(f, argnums=(0, 1))(bp, xx)
            args = (_drop_layer_dim(params["blocks"]), x)
            in_sh = (_drop_layer_spec(pspecs["blocks"]), xspec)
        add("block", block_step, args, in_sh, plan.n_super - 1)
        if plan.tail:
            def tail_step(tp, xx):
                def f(tp_, x_):
                    return jnp.sum(lm.tail_fwd(tp_, x_, cfg, rt)
                                   .astype(jnp.float32))
                return jax.grad(f, argnums=(0, 1))(tp, xx)
            add("tail", tail_step,
                (_drop_layer_dim(params["tail"]), x),
                (_drop_layer_spec(pspecs["tail"]), xspec),
                len(plan.tail) - 1)
        return parts

    if shape.kind == "prefill":
        if use_shared:
            def block_step(bp, sh, xx):
                return lm.superblock_fwd(bp, sh, xx, cfg, rt)[0]
            args = (_drop_layer_dim(params["blocks"]), shared, x)
            in_sh = (_drop_layer_spec(pspecs["blocks"]), shared_spec, xspec)
        else:
            def block_step(bp, xx):
                return lm.superblock_fwd(bp, None, xx, cfg, rt)[0]
            args = (_drop_layer_dim(params["blocks"]), x)
            in_sh = (_drop_layer_spec(pspecs["blocks"]), xspec)
        add("block", block_step, args, in_sh, plan.n_super - 1)
        if plan.tail:
            add("tail", lambda tp, xx: lm.tail_fwd(tp, xx, cfg, rt),
                (_drop_layer_dim(params["tail"]), x),
                (_drop_layer_spec(pspecs["tail"]), xspec),
                len(plan.tail) - 1)
        return parts

    # decode
    _, cache = decode_input_specs(cfg, shape)
    cspecs = shd.repair_specs(cache, shd.cache_spec(cache, mesh), mesh)
    blk_cache = _drop_layer_dim(cache["blocks"])
    blk_cspec = _drop_layer_spec(cspecs["blocks"])
    if use_shared:
        def block_step(bp, sh, cc, xx):
            return lm.superblock_decode(bp, sh, cc, xx, cfg, rt)
        args = (_drop_layer_dim(params["blocks"]), shared, blk_cache, x)
        in_sh = (_drop_layer_spec(pspecs["blocks"]), shared_spec,
                 blk_cspec, xspec)
        donate = (2,)
    else:
        def block_step(bp, cc, xx):
            return lm.superblock_decode(bp, None, cc, xx, cfg, rt)
        args = (_drop_layer_dim(params["blocks"]), blk_cache, x)
        in_sh = (_drop_layer_spec(pspecs["blocks"]), blk_cspec, xspec)
        donate = (1,)
    # cache donation mirrors the full decode step (the in-place scatter
    # update must not be charged a whole-cache copy)
    add("block", block_step, args, in_sh, plan.n_super - 1, donate=donate)
    if plan.tail:
        add("tail",
            lambda tp, cc, xx: lm.tail_decode(tp, cc, xx, cfg, rt),
            (_drop_layer_dim(params["tail"]), _drop_layer_dim(cache["tail"]),
             x),
            (_drop_layer_spec(pspecs["tail"]), _drop_layer_spec(cspecs["tail"]),
             xspec),
            len(plan.tail) - 1, donate=(1,))
    return parts


def resolve_rt(cfg: ModelConfig, mesh: Mesh, rt: RuntimeConfig
               ) -> RuntimeConfig:
    """Resolve launcher-decided knobs ('auto' values) from cfg x mesh."""
    if rt.moe_constraint == "auto":
        if not cfg.n_experts or rt.moe_dispatch != "grouped" \
                or "data" not in mesh.axis_names:
            choice = "none"
        elif cfg.n_experts % mesh.shape["data"] == 0:
            choice = "experts"
        else:
            choice = "tokens"
        rt = dataclasses.replace(rt, moe_constraint=choice)
    return rt


def plan_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              rt: RuntimeConfig,
              rules: shd.ShardingRules = shd.ShardingRules()
              ) -> CellLowering:
    rt = resolve_rt(cfg, mesh, rt)
    params, axes, opt = abstract_state(cfg, with_opt=shape.kind == "train")
    pspecs = shd.param_specs(axes, rules, mesh)
    pspecs = shd.repair_specs(params, pspecs, mesh)

    if shape.kind == "train":
        step = make_train_step(cfg, rt, default_opt_config())
        batch = input_specs(cfg, shape)
        ospecs = shd.opt_state_specs(pspecs, mesh)
        bspecs = _maybe_batch_spec(batch, mesh)
        metric_specs = None
        return CellLowering(
            step=step,
            args=(params, opt, batch),
            in_shardings=(pspecs, ospecs, bspecs),
            out_shardings=(pspecs, ospecs, metric_specs),
            donate_argnums=(0, 1),
        )
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, rt)
        batch = input_specs(cfg, shape)
        bspecs = _maybe_batch_spec(batch, mesh)
        return CellLowering(
            step=step, args=(params, batch),
            in_shardings=(pspecs, bspecs),
            out_shardings=None)
    # decode
    step = make_decode_step(cfg, rt)
    tokens, cache = decode_input_specs(cfg, shape)
    cspecs = shd.repair_specs(cache, shd.cache_spec(cache, mesh), mesh)
    tspecs = _maybe_batch_spec(tokens, mesh)
    return CellLowering(
        step=step, args=(params, cache, tokens),
        in_shardings=(pspecs, cspecs, tspecs),
        out_shardings=(None, cspecs),
        donate_argnums=(1,),
    )
