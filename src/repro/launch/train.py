"""End-to-end training driver: sharded train step, deterministic data,
atomic checkpoints with auto-resume, straggler watchdog, failure injection.

This is the same ``train_step`` the dry-run lowers for the production
meshes; on CPU it runs a reduced config on the host mesh so the examples
and integration tests exercise the full loop (including kill/resume)
end-to-end.

Usage:
  python -m repro.launch.train --arch deepseek-7b --reduced --steps 100
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.checkpoint import checkpointer as ckpt
from repro.configs import LM_SHAPES, get_config
from repro.configs.base import ModelConfig, RuntimeConfig, ShapeConfig
from repro.data import pipeline as data_mod
from repro.distributed import compression
from repro.distributed import data_parallel as dp_mod
from repro.distributed import fault_tolerance as ft
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod
from repro.models import lm
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    arch: str = "deepseek-7b"
    shape: str = "train_4k"
    reduced: bool = True               # CPU-runnable variant
    steps: int = 100
    mode: str = "xla"                  # 'brainslug' | 'xla' | 'barrier'
    remat: str = "none"
    ckpt_dir: str = ""
    ckpt_every: int = 25
    log_every: int = 10
    seed: int = 0
    batch_override: int | None = None
    seq_override: int | None = None
    lr: float = 3e-3
    # explicit data-parallel driver: shard_map step over the mesh "data"
    # axis with a hand-written gradient all-reduce (see
    # repro.distributed.data_parallel) instead of the GSPMD default
    data_parallel: bool = False
    compress: bool = False             # int8 error-feedback grad payload
    mesh_devices: int | None = None    # force an n-device test mesh
    # arbitrary ModelConfig field overrides (applied after reduction) —
    # lets examples size custom models without a new registry entry
    config_overrides: tuple = ()       # of (field, value) pairs


@dataclasses.dataclass
class Trainer:
    tc: TrainerConfig
    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Any
    step_fn: Callable
    params: Any
    opt_state: Any
    start_step: int
    watchdog: ft.StragglerWatchdog
    checkpointer: ckpt.AsyncCheckpointer | None
    history: list

    def run(self, failure_hook: Callable[[int], None] | None = None
            ) -> list[dict]:
        pipe = data_mod.Pipeline(
            self.cfg, self.shape,
            data_mod.DataConfig(seed=self.tc.seed),
            start_step=self.start_step,
            batch_override=self.shape.global_batch)
        try:
            for step, batch in pipe:
                if step >= self.tc.steps:
                    break
                if failure_hook is not None:
                    failure_hook(step)
                self.watchdog.start()
                dev_batch = jax.tree_util.tree_map(jnp.asarray, batch)
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, dev_batch)
                loss = float(metrics["loss"])
                slow = self.watchdog.stop()
                rec = {"step": step, "loss": loss,
                       "grad_norm": float(metrics["grad_norm"]),
                       "slow": bool(slow)}
                self.history.append(rec)
                if step % self.tc.log_every == 0:
                    print(f"[train] step={step} loss={loss:.4f} "
                          f"gnorm={rec['grad_norm']:.3f}", flush=True)
                if (self.checkpointer is not None and step > 0
                        and step % self.tc.ckpt_every == 0):
                    self.checkpointer.submit(
                        step, {"params": self.params,
                               "opt": self.opt_state},
                        extra={"next_step": step + 1, "loss": loss})
            if self.checkpointer is not None:
                last = self.tc.steps - 1
                self.checkpointer.submit(
                    self.tc.steps,
                    {"params": self.params, "opt": self.opt_state},
                    extra={"next_step": self.tc.steps,
                           "loss": self.history[-1]["loss"]
                           if self.history else float("nan")})
                self.checkpointer.wait()
        finally:
            pipe.close()
        return self.history


def build_trainer(tc: TrainerConfig) -> Trainer:
    cfg = get_config(tc.arch)
    shape = LM_SHAPES[tc.shape]
    if tc.reduced:
        cfg = cfg.reduced()
        shape = shape.reduced()
    if tc.config_overrides:
        cfg = dataclasses.replace(cfg, **dict(tc.config_overrides))
    if tc.batch_override:
        shape = dataclasses.replace(shape, global_batch=tc.batch_override)
    if tc.seq_override:
        shape = dataclasses.replace(shape, seq_len=tc.seq_override)

    mesh = (mesh_mod.make_test_mesh(tc.mesh_devices) if tc.mesh_devices
            else mesh_mod.make_host_mesh())
    rt = RuntimeConfig(mode=tc.mode, remat=tc.remat, interpret=True)
    rules = shd.ShardingRules()

    params, axes = lm.init(jax.random.PRNGKey(tc.seed), cfg)
    pspecs = shd.repair_specs(params, shd.param_specs(axes, rules, mesh),
                              mesh)
    opt_cfg = adamw.AdamWConfig(
        lr=tc.lr if tc.reduced else steps_mod.default_opt_config().lr)
    opt_state = adamw.init(params)

    if tc.data_parallel:
        # explicit shard_map data-parallel step: params/opt replicated,
        # batch sharded over "data", hand-written (optionally compressed)
        # gradient all-reduce inside the region
        dpc = dp_mod.DPConfig(compress=tc.compress)

        def loss(p, b):
            return lm.loss_fn(p, b, cfg, rt)

        raw_step = dp_mod.make_dp_train_step(loss, opt_cfg, mesh, dpc)
        opt_state = {"opt": opt_state}
        if tc.compress:
            opt_state["err"] = compression.init_error_state(params)

        def dp_step(p, opt_wrap, batch):
            state = {"params": p, "opt": opt_wrap["opt"]}
            if "err" in opt_wrap:
                state["err"] = opt_wrap["err"]
            new_state, metrics = raw_step(state, batch)
            ow = {k: new_state[k] for k in opt_wrap}
            return new_state["params"], ow, metrics

        with mesh:
            step_fn = jax.jit(dp_step, donate_argnums=(0, 1))
    else:
        step = steps_mod.make_train_step(cfg, rt, opt_cfg)
        ospecs = shd.opt_state_specs(pspecs, mesh)
        bspecs = steps_mod._maybe_batch_spec(
            steps_mod.input_specs(cfg, shape), mesh)

        def to_sh(tree):
            return jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), tree,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

        with mesh:
            step_fn = jax.jit(step,
                              in_shardings=(to_sh(pspecs), to_sh(ospecs),
                                            to_sh(bspecs)),
                              donate_argnums=(0, 1))

    # ---- auto-resume -------------------------------------------------------
    start_step = 0
    checkpointer = None
    if tc.ckpt_dir:
        # robust resume: crash orphans are swept, a truncated latest
        # checkpoint falls back to the previous complete one
        restored = ckpt.restore_latest(tc.ckpt_dir,
                                       {"params": params, "opt": opt_state})
        if restored is not None:
            tree, extra, latest = restored
            params, opt_state = tree["params"], tree["opt"]
            if tc.data_parallel and tc.compress:
                # the saved residual compensated a quantization the saved
                # params already absorbed — replaying it would apply that
                # correction twice; resume restarts the feedback loop
                opt_state = {**opt_state, "err": compression.
                             reset_error_state(opt_state["err"])}
            start_step = int(extra.get("next_step", latest))
            print(f"[train] resumed from step {latest} "
                  f"(next_step={start_step})", flush=True)
        checkpointer = ckpt.AsyncCheckpointer(tc.ckpt_dir)

    return Trainer(tc=tc, cfg=cfg, shape=shape, mesh=mesh, step_fn=step_fn,
                   params=params, opt_state=opt_state,
                   start_step=start_step,
                   watchdog=ft.StragglerWatchdog(),
                   checkpointer=checkpointer, history=[])


def train(tc: TrainerConfig,
          failure_hook: Callable[[int], None] | None = None) -> list[dict]:
    trainer = build_trainer(tc)
    try:
        return trainer.run(failure_hook)
    finally:
        if trainer.checkpointer is not None:
            trainer.checkpointer.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--mode", default="xla",
                    choices=["brainslug", "xla", "barrier"])
    ap.add_argument("--remat", default="none")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--data-parallel", action="store_true",
                    help="explicit shard_map DP step (hand-written "
                         "gradient all-reduce)")
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression "
                         "(requires --data-parallel)")
    ap.add_argument("--mesh-devices", type=int, default=None,
                    help="force an n-device test mesh "
                         "(host platform devices)")
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    tc = TrainerConfig(arch=args.arch, shape=args.shape, steps=args.steps,
                       mode=args.mode, remat=args.remat,
                       reduced=args.reduced, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every,
                       batch_override=args.batch, seq_override=args.seq,
                       lr=args.lr, data_parallel=args.data_parallel,
                       compress=args.compress,
                       mesh_devices=args.mesh_devices)
    t0 = time.time()
    history = train(tc)
    dt = time.time() - t0
    if history:
        print(f"[train] done: {len(history)} steps in {dt:.1f}s, "
              f"loss {history[0]['loss']:.4f} -> {history[-1]['loss']:.4f}",
              flush=True)
    if args.metrics_out:
        os.makedirs(os.path.dirname(args.metrics_out) or ".", exist_ok=True)
        with open(args.metrics_out, "w") as f:
            json.dump(history, f)
    return 0


if __name__ == "__main__":
    sys.exit(main())
