"""Batched serving driver: prefill + decode loop with a static KV/SSM cache.

The serving model is the classic two-phase one: a batch of requests is
prefilled (full-sequence forward, last-position logits), then tokens are
generated step-by-step through ``lm.decode_step`` — the same function the
decode dry-run cells lower for the production meshes.  Greedy or
temperature sampling; per-request stop lengths (continuous-batching slot
semantics: finished requests keep cycling a pad token, their cache slots
are reusable).

Usage:
  python -m repro.launch.serve --arch qwen2.5-14b --reduced --new-tokens 16
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LM_SHAPES, get_config
from repro.configs.base import ModelConfig, RuntimeConfig
from repro.models import lm


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    arch: str = "qwen2.5-14b"
    reduced: bool = True
    mode: str = "xla"
    batch: int = 4
    prompt_len: int = 16
    new_tokens: int = 16
    max_len: int = 64
    temperature: float = 0.0           # 0 = greedy
    seed: int = 0


class Server:
    """Holds jitted prefill/decode callables + the mutable cache."""

    def __init__(self, sc: ServeConfig):
        cfg = get_config(sc.arch)
        if sc.reduced:
            cfg = cfg.reduced()
        if not cfg.supports_decode:
            raise ValueError(f"{sc.arch} is encoder-only; no decode path")
        if cfg.frontend == "vision_patches":
            cfg = dataclasses.replace(cfg, frontend=None, n_prefix_tokens=0)
        self.cfg = cfg
        self.sc = sc
        self.rt = RuntimeConfig(mode=sc.mode, interpret=True)
        self.params, _ = lm.init(jax.random.PRNGKey(sc.seed), cfg)

        cfg_, rt_ = self.cfg, self.rt

        @jax.jit
        def decode_fn(params, cache, tok):
            return lm.decode_step(params, cache, tok, cfg_, rt_)

        @jax.jit
        def prefill_fn(params, cache, tokens):
            # One jitted dispatch for the whole prompt: position 0 seeds the
            # carry (logit dtype/shape come from the model, not a guess),
            # the fori_loop rolls the remaining positions inside the jit.
            logits, cache = lm.decode_step(params, cache, tokens[:, :1],
                                           cfg_, rt_)

            def body(t, carry):
                _, cache = carry
                tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
                return lm.decode_step(params, cache, tok, cfg_, rt_)

            return jax.lax.fori_loop(1, tokens.shape[1], body,
                                     (logits, cache))

        self._decode = decode_fn
        self._prefill = prefill_fn

    def prefill(self, tokens: jnp.ndarray) -> tuple[Any, jnp.ndarray]:
        """Ingest the prompt (cache-building prefill) in a single jitted
        dispatch.  Returns (cache, last-token logits)."""
        b, s = tokens.shape
        cache = lm.init_decode_cache(self.cfg, b, self.sc.max_len,
                                     dtype=jnp.float32)
        if s == 0:
            # Zero-length prompts have no last-token logits; generation
            # starts from all-zero logits (greedy decodes the pad token 0)
            # instead of crashing on ``logits[:, 0]`` with logits = None.
            return cache, jnp.zeros((b, self.cfg.vocab_size), jnp.float32)
        logits, cache = self._prefill(self.params, cache,
                                      jnp.asarray(tokens))
        return cache, logits[:, 0]

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.sc.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray,
                 stop_lengths: np.ndarray | None = None) -> np.ndarray:
        """prompts: (B, P) int32.  Returns (B, new_tokens) generations."""
        sc = self.sc
        tokens = jnp.asarray(prompts, jnp.int32)
        cache, logits = self.prefill(tokens)
        key = jax.random.PRNGKey(sc.seed + 1)
        outs = []
        stops = (np.full((tokens.shape[0],), sc.new_tokens)
                 if stop_lengths is None else stop_lengths)
        for i in range(sc.new_tokens):
            key, sub = jax.random.split(key)
            nxt = self._sample(logits, sub)
            done = i >= stops
            nxt = jnp.where(jnp.asarray(done), 0, nxt)      # pad finished
            outs.append(np.asarray(nxt))
            logits_full, cache = self._decode(self.params, cache,
                                              nxt[:, None])
            logits = logits_full[:, 0]
        return np.stack(outs, axis=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--mode", default="xla",
                    choices=["brainslug", "xla", "barrier"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    sc = ServeConfig(arch=args.arch, mode=args.mode, batch=args.batch,
                     prompt_len=args.prompt_len, new_tokens=args.new_tokens,
                     max_len=args.prompt_len + args.new_tokens + 1,
                     temperature=args.temperature)
    server = Server(sc)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, server.cfg.vocab_size,
                           (sc.batch, sc.prompt_len)).astype(np.int32)
    t0 = time.time()
    gen = server.generate(prompts)
    dt = time.time() - t0
    tput = sc.batch * sc.new_tokens / dt
    print(f"[serve] {sc.batch} requests x {sc.new_tokens} tokens "
          f"in {dt:.2f}s ({tput_fmt(tput)})")
    print("[serve] first generation:", gen[0].tolist())
    return 0


def tput_fmt(tput: float) -> str:
    return f"{tput:.1f} tok/s"


if __name__ == "__main__":
    sys.exit(main())
