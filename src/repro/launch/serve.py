"""Batched serving drivers: the static two-phase loop and the
continuous-batching engine.

Two drivers share one model (params, jitted ``lm.decode_step`` family):

* ``Server.generate`` — the classic static path: a rectangular batch is
  prefilled, then decoded in lock-step.  Kept as the parity baseline; its
  historical defects are fixed here: the loop stops as soon as every
  request has passed its stop length (and dispatches nothing at all when
  ``stops.max() == 0``), the prompt shape is validated against
  ``ServeConfig`` and against the cache ``max_len``, and each call draws a
  fresh RNG stream (per-call ``fold_in`` on a call counter) instead of
  replaying ``PRNGKey(seed + 1)`` forever.
* ``Server.engine()`` — builds a :class:`repro.launch.engine.Engine` over
  the same params: slot-managed KV cache, queue admission, one jitted
  mixed prefill/decode step.  Use it for ragged traffic.

Dispatch accounting: the static driver records into ``STATS`` (runtime
keys — ``prefill`` / ``decode`` dispatches plus ``decode_slot_steps``, the
slot-units of decode work including the pad cycling of finished requests)
and exposes a per-run :class:`~repro.core.scheduler.ServeStats` via
``Server.last_stats`` for throughput comparisons against the engine.

Usage:
  python -m repro.launch.serve --arch qwen2.5-14b --reduced --new-tokens 16
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import RuntimeConfig
from repro.core.scheduler import ServeStats
from repro.kernels.fused_stack.ops import DispatchStats
from repro.launch import engine as engine_mod
from repro.models import lm

STATS = DispatchStats(keys=("prefill", "decode", "decode_slot_steps",
                            "generated_tokens"))


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    arch: str = "qwen2.5-14b"
    reduced: bool = True
    mode: str = "xla"
    batch: int = 4
    prompt_len: int = 16
    new_tokens: int = 16
    max_len: int = 64
    temperature: float = 0.0           # 0 = greedy
    seed: int = 0


class Server:
    """Holds jitted prefill/decode callables + the mutable cache."""

    def __init__(self, sc: ServeConfig):
        cfg = get_config(sc.arch)
        if sc.reduced:
            cfg = cfg.reduced()
        if not cfg.supports_decode:
            raise ValueError(f"{sc.arch} is encoder-only; no decode path")
        if cfg.frontend == "vision_patches":
            cfg = dataclasses.replace(cfg, frontend=None, n_prefix_tokens=0)
        self.cfg = cfg
        self.sc = sc
        self.rt = RuntimeConfig(mode=sc.mode, interpret=True)
        self.params, _ = lm.init(jax.random.PRNGKey(sc.seed), cfg)
        self.last_stats: ServeStats | None = None
        self.last_dispatch: dict[str, int] | None = None
        self._n_calls = 0

        cfg_, rt_ = self.cfg, self.rt

        @jax.jit
        def decode_fn(params, cache, tok):
            return lm.decode_step(params, cache, tok, cfg_, rt_)

        @jax.jit
        def prefill_fn(params, cache, tokens):
            # One jitted dispatch for the whole prompt: position 0 seeds the
            # carry (logit dtype/shape come from the model, not a guess),
            # the fori_loop rolls the remaining positions inside the jit.
            logits, cache = lm.decode_step(params, cache, tokens[:, :1],
                                           cfg_, rt_)

            def body(t, carry):
                _, cache = carry
                tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
                return lm.decode_step(params, cache, tok, cfg_, rt_)

            return jax.lax.fori_loop(1, tokens.shape[1], body,
                                     (logits, cache))

        self._decode = decode_fn
        self._prefill = prefill_fn

    def engine(self, *, slots: int | None = None, prefill_chunk: int = 8,
               seed: int | None = None, kv_layout: str | None = None,
               kv_block_size: int | None = None,
               kv_num_blocks: int | None = None,
               prefix_sharing: bool = True,
               verify_mode: str = "warn", mesh=None,
               serve_partition: str | None = None) -> engine_mod.Engine:
        """A continuous-batching :class:`~repro.launch.engine.Engine` over
        this server's params/config (``slots`` defaults to the static
        batch width; the cache budget is the same ``max_len``).

        ``kv_layout``/``kv_block_size`` override the runtime config's KV
        cache layout for this engine (``"paged"`` swaps the dense per-slot
        reservation for the block pool; see ``launch/engine.py``).
        ``mesh`` runs the engine's mixed step in a shard_map region over a
        device mesh (``launch.mesh.make_test_mesh`` /
        ``make_production_mesh``); ``serve_partition`` restricts which
        mesh axes the decode-cache plan may use (``'auto'`` | ``'none'`` |
        ``'data'`` | ``'tensor'`` | ``'both'``).  The remaining knobs pass
        through to the Engine."""
        rt = self.rt
        if (kv_layout is not None or kv_block_size is not None
                or serve_partition is not None):
            rt = dataclasses.replace(
                rt,
                kv_layout=rt.kv_layout if kv_layout is None else kv_layout,
                kv_block_size=(rt.kv_block_size if kv_block_size is None
                               else kv_block_size),
                serve_partition=(rt.serve_partition
                                 if serve_partition is None
                                 else serve_partition))
        return engine_mod.Engine(
            self.cfg, self.params, rt,
            slots=self.sc.batch if slots is None else slots,
            max_len=self.sc.max_len, prefill_chunk=prefill_chunk,
            seed=self.sc.seed if seed is None else seed,
            kv_num_blocks=kv_num_blocks, prefix_sharing=prefix_sharing,
            verify_mode=verify_mode, mesh=mesh)

    def prefill(self, tokens: jnp.ndarray) -> tuple[Any, jnp.ndarray]:
        """Ingest the prompt (cache-building prefill) in a single jitted
        dispatch.  Returns (cache, last-token logits)."""
        b, s = tokens.shape
        if s > self.sc.max_len:
            raise ValueError(
                f"prompt length {s} exceeds cache max_len = "
                f"{self.sc.max_len}; the prefill would write past the end "
                f"of the KV cache")
        cache = lm.init_decode_cache(self.cfg, b, self.sc.max_len,
                                     dtype=jnp.float32)
        if s == 0:
            # Zero-length prompts have no last-token logits; generation
            # starts from all-zero logits (greedy decodes the pad token 0)
            # instead of crashing on ``logits[:, 0]`` with logits = None.
            return cache, jnp.zeros((b, self.cfg.vocab_size), jnp.float32)
        STATS.record("prefill")
        logits, cache = self._prefill(self.params, cache,
                                      jnp.asarray(tokens))
        return cache, logits[:, 0]

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.sc.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray,
                 stop_lengths: np.ndarray | None = None,
                 key: jnp.ndarray | None = None) -> np.ndarray:
        """prompts: (B, P) int32.  Returns (B, new_tokens) generations;
        rows are zero-padded past their stop length.

        ``key`` overrides the sampling key for this call; by default each
        call folds a call counter into ``PRNGKey(seed + 1)``, so repeated
        temperature-sampled calls draw distinct streams (pass an explicit
        key to reproduce a call).
        """
        sc = self.sc
        prompts = np.asarray(prompts)
        if prompts.ndim != 2 or prompts.shape != (sc.batch, sc.prompt_len):
            raise ValueError(
                f"prompts shape {tuple(prompts.shape)} does not match "
                f"ServeConfig(batch={sc.batch}, prompt_len={sc.prompt_len})")
        if sc.prompt_len + sc.new_tokens > sc.max_len:
            raise ValueError(
                f"prompt_len + new_tokens = {sc.prompt_len} + "
                f"{sc.new_tokens} exceeds cache max_len = {sc.max_len}; "
                f"the generation would write past the end of the KV cache")
        b = sc.batch
        stops = (np.full((b,), sc.new_tokens)
                 if stop_lengths is None else np.asarray(stop_lengths))
        if stops.shape != (b,):
            raise ValueError(
                f"stop_lengths shape {tuple(stops.shape)} does not match "
                f"the batch: expected ({b},)")
        stops = np.clip(stops, 0, sc.new_tokens)
        out = np.zeros((b, sc.new_tokens), np.int32)
        stats = ServeStats(n_requests=b, n_slots=b)
        # per-call dispatch delta: STATS is process-cumulative, a second
        # generate() must still report only its own dispatches
        stats_before = STATS.snapshot()
        t0 = time.perf_counter()

        # Every request at stop length 0 => nothing to generate: return the
        # all-pad result without a single dispatch (not even the prefill).
        live_steps = int(stops.max()) if b else 0
        if live_steps == 0:
            self.last_stats = stats
            self.last_dispatch = STATS.delta(stats_before)
            return out

        cache, logits = self.prefill(jnp.asarray(prompts, jnp.int32))
        if sc.prompt_len > 0:           # empty prompts dispatch nothing
            stats.step_dispatches += 1
            stats.prefill_tokens += b * sc.prompt_len
        if key is None:
            key = jax.random.fold_in(jax.random.PRNGKey(sc.seed + 1),
                                     self._n_calls)
        self._n_calls += 1
        for i in range(live_steps):
            key, sub = jax.random.split(key)
            nxt = self._sample(logits, sub)
            done = i >= stops
            nxt = jnp.where(jnp.asarray(done), 0, nxt)      # pad finished
            out[:, i] = np.asarray(nxt)
            n_live = int((~done).sum())
            stats.generated_tokens += n_live
            STATS.record("generated_tokens", n_live)
            # The loop used to march all new_tokens steps, cycling pad
            # tokens through full decode dispatches long after done.all().
            # The last sampled step needs no further logits either: the
            # final decode is skipped too.
            if i + 1 < live_steps:
                STATS.record("decode")
                STATS.record("decode_slot_steps", b)
                stats.step_dispatches += 1
                stats.decode_slot_steps += b
                # slots whose request is already past its stop length only
                # cycle a pad token through this dispatch — the waste the
                # continuous-batching engine exists to remove
                stats.padded_decode_slot_steps += b - int((i + 1 < stops).sum())
                logits_full, cache = self._decode(self.params, cache,
                                                  nxt[:, None])
                logits = logits_full[:, 0]
        stats.completed = b
        stats.admitted = b
        stats.wall_s = time.perf_counter() - t0
        self.last_stats = stats
        self.last_dispatch = STATS.delta(stats_before)
        return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--mode", default="xla",
                    choices=["brainslug", "xla", "barrier"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    sc = ServeConfig(arch=args.arch, mode=args.mode, batch=args.batch,
                     prompt_len=args.prompt_len, new_tokens=args.new_tokens,
                     max_len=args.prompt_len + args.new_tokens + 1,
                     temperature=args.temperature)
    server = Server(sc)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, server.cfg.vocab_size,
                           (sc.batch, sc.prompt_len)).astype(np.int32)
    t0 = time.time()
    gen = server.generate(prompts)
    dt = time.time() - t0
    tput = sc.batch * sc.new_tokens / dt
    print(f"[serve] {sc.batch} requests x {sc.new_tokens} tokens "
          f"in {dt:.2f}s ({tput_fmt(tput)})")
    print("[serve] first generation:", gen[0].tolist())
    return 0


def tput_fmt(tput: float) -> str:
    return f"{tput:.1f} tok/s"


if __name__ == "__main__":
    sys.exit(main())
