import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512")
# The lines above MUST run before any jax import anywhere in the process:
# jax locks the device count at first backend initialization.  An explicit
# externally-set device count (tests use 8) is respected.

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces (and caches as JSON under ``--out``):
  * ``memory_analysis`` — per-device argument/output/temp bytes (fits?)
  * ``cost_analysis``   — HLO FLOPs and bytes-accessed for §Roofline
  * per-collective byte totals parsed from the compiled HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), the collective-roofline numerator.

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --skip-existing
"""

import argparse
import dataclasses
import json
import re
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding

from repro.configs import ARCH_IDS, applicable_shapes, get_config
from repro.configs.base import RuntimeConfig
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _cost_dict(cost) -> dict:
    """Normalize Compiled.cost_analysis() across jax versions (older
    releases return a one-element list of dicts)."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost


def parse_collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-buffer bytes per collective kind.  Result size is the
    per-device traffic proxy: all-reduce result == operand; all-gather
    result == bytes received; all-to-all/collective-permute result == bytes
    moved; reduce-scatter uses operand ~= result * group (approximated by
    result here, noted in EXPERIMENTS)."""
    totals = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None or f"{kind}-done(" in rhs:
            continue                      # count start, not done
        head = rhs.split("(", 1)[0]
        nbytes = 0
        for dt, dims in shape_re.findall(head):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        totals[kind] += nbytes
        counts[kind] += 1
    return {"bytes": totals, "counts": counts}


def _to_shardings(tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if s is not None else None, tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        or x is None)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             rt: RuntimeConfig | None = None,
             rules: shd.ShardingRules | None = None) -> dict:
    cfg = get_config(arch)
    shapes = applicable_shapes(cfg)
    if shape_name not in shapes:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped",
                "reason": "shape not applicable to this arch family"}
    shape = shapes[shape_name]
    mesh = mesh_mod.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rt = rt or RuntimeConfig(
        mode="xla", remat="dots",
        fused_loss_chunk=512 if shape.kind == "train" else 0,
        loss_unroll=True)
    rules = rules or shd.ShardingRules()

    t0 = time.time()
    cell = steps_mod.plan_cell(cfg, shape, mesh, rt, rules)
    with mesh:
        jitted = jax.jit(
            cell.step,
            in_shardings=_to_shardings(cell.in_shardings, mesh),
            out_shardings=_to_shardings(cell.out_shardings, mesh),
            donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled.cost_analysis())
    coll = parse_collective_bytes(compiled.as_text())

    # ---- trip-count correction: XLA counts scan bodies once; add
    # (trip_count - 1) x the straight-line cost of one scanned super-block.
    corrected = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": {k: float(v) for k, v in coll["bytes"].items()},
    }
    parts_out = {}
    for pname, plow, mult in steps_mod.plan_part_cells(
            cfg, shape, mesh, rt, rules):
        with mesh:
            pc = jax.jit(
                plow.step,
                in_shardings=_to_shardings(plow.in_shardings, mesh),
                out_shardings=plow.out_shardings,
                donate_argnums=plow.donate_argnums,
            ).lower(*plow.args).compile()
        pcost = _cost_dict(pc.cost_analysis())
        pcoll = parse_collective_bytes(pc.as_text())
        parts_out[pname] = {
            "flops": float(pcost.get("flops", 0.0)),
            "bytes_accessed": float(pcost.get("bytes accessed", 0.0)),
            "collectives": pcoll,
            "multiplier": mult,
        }
        corrected["flops"] += mult * parts_out[pname]["flops"]
        corrected["bytes_accessed"] += \
            mult * parts_out[pname]["bytes_accessed"]
        for k, v in pcoll["bytes"].items():
            corrected["collective_bytes"][k] += mult * float(v)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok",
        "kind": shape.kind,
        "n_devices": mesh.devices.size,
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", 0),
        },
        "collectives": coll,
        "parts": parts_out,
        "corrected": corrected,
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "timings": {"lower_s": round(t_lower, 2),
                    "compile_s": round(t_compile, 2)},
    }
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    # ---- perf-iteration knobs (EXPERIMENTS.md §Perf) -----------------------
    ap.add_argument("--remat", default="dots",
                    choices=["none", "dots", "full"])
    ap.add_argument("--moe-dispatch", default="global",
                    choices=["global", "grouped"],
                    help="global = paper-faithful baseline dispatch")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate params over the data axis (serving "
                         "layout: kills per-step weight all-gathers)")
    ap.add_argument("--loss-chunk", type=int, default=512)
    args = ap.parse_args(argv)

    rt = RuntimeConfig(mode="xla", remat=args.remat,
                       moe_dispatch=args.moe_dispatch,
                       moe_constraint=("auto" if args.moe_dispatch
                                       == "grouped" else "none"),
                       loss_unroll=True)
    rules = shd.ShardingRules(fsdp=not args.no_fsdp)
    _loss_chunk = args.loss_chunk

    archs = args.arch or (list(ARCH_IDS) if args.all else ["deepseek-7b"])
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        shape_names = args.shape or list(applicable_shapes(cfg))
        for shape_name in shape_names:
            for mesh_kind in meshes:
                tag = f"{arch}__{shape_name}__{mesh_kind}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                print(f"[cell] {tag} ...", flush=True)
                is_train = shape_name.startswith("train")
                cell_rt = dataclasses.replace(
                    rt, fused_loss_chunk=_loss_chunk if is_train else 0)
                try:
                    res = run_cell(arch, shape_name, mesh_kind,
                                   rt=cell_rt, rules=rules)
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures += 1
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                if res["status"] == "ok":
                    print(f"  flops={res['flops']:.3e} "
                          f"bytes={res['bytes_accessed']:.3e} "
                          f"coll={sum(res['collectives']['bytes'].values()):.3e} "
                          f"temp={res['memory']['temp_bytes']/2**30:.2f}GiB "
                          f"compile={res['timings']['compile_s']}s",
                          flush=True)
                else:
                    print(f"  {res['status']}: {res.get('reason', res.get('error', ''))[:300]}",
                          flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
