"""Static verification CLI: ``python -m repro.lint``.

Runs the :mod:`repro.core.verify` pass over every shipped architecture
without executing a single kernel: for each LM arch the block stack
programs (`repro.layers.stacks`) are instantiated at the config's real
dimensions, collapsed for the target device in both inference and
training sizing, and every invariant family is checked — program
well-formedness, plan legality (partition / tile coverage / halo
arithmetic / VMEM budget), differentiability coverage, and the
pallas-grid write model of every kernel the plan would compile to.
``brainslug-cnn`` verifies the full VGG NetGraph end to end (graph SSA +
dead values, then each nhwc stack segment); ``paged-kv`` self-tests the
serve engine's block-table soundness family (``kv.*``) against a seeded
mutant; ``serve-dist`` does the same for the serving decode-cache
partition family (``dist.serve-*``).

Exit status is 1 when any *error*-severity finding survives; warnings
are reported but do not fail the run.  ``--out`` writes the full finding
list as JSON (the CI lint job uploads it as an artifact).

Usage:
  python -m repro.lint                       # all archs, report to stdout
  python -m repro.lint --arch deepseek-7b --arch brainslug-cnn
  python -m repro.lint --out results/lint/verify_report.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

from types import SimpleNamespace

from repro.core import analyzer, collapse, ir, partition, resource
from repro.core import api as core_api
from repro.core import verify

#: Default row count stack programs are verified at (any multiple of the
#: sublane works; plans are re-derived per shape at optimize() time anyway).
_ROWS = 512

_DEVICES = {"tpu_v5e": resource.TPU_V5E, "tiny": resource.TINY_DEVICE}

#: Production-shaped synthetic mesh the ``dist.*`` family is linted
#: against — 4-way data x 2-way model, no devices needed (the planner and
#: verifier reason about :class:`repro.core.partition.MeshAxes` only).
_DIST_AXES = partition.MeshAxes(("data", "model"), (4, 2))


def lint_program(program: ir.StackProgram,
                 shapes: dict[str, tuple[int, ...]],
                 device: resource.DeviceSpec,
                 itemsize: int) -> list[verify.Finding]:
    """Verify one stack program end to end: well-formedness, then a
    collapse under both inference and training sizing with plan-legality
    and write-model checks on each."""
    fs = verify.check_program(program, shapes=shapes)
    if verify.errors(fs):
        return fs                    # collapse needs a well-formed program
    for differentiable in (False, True):
        try:
            plan = collapse.collapse(program, shapes, device,
                                     itemsize=itemsize,
                                     differentiable=differentiable)
        except Exception as e:  # noqa: BLE001 — a lint must not crash
            fs.append(verify.Finding(
                "plan.budget-exceeded", "error", program.name,
                f"collapse failed ({'train' if differentiable else 'infer'}"
                f" sizing): {type(e).__name__}: {e}"))
            continue
        fs.extend(verify.check_plan(plan, itemsize=itemsize,
                                    differentiable=differentiable))
        if differentiable:
            fs.extend(verify.check_differentiable(program))
        for spec in verify.plan_write_specs(plan,
                                            differentiable=differentiable):
            fs.extend(verify.check_write_spec(spec))
    return fs


def lint_dist_program(program: ir.StackProgram,
                      shapes: dict[str, tuple[int, ...]],
                      device: resource.DeviceSpec, itemsize: int,
                      axes: partition.MeshAxes = _DIST_AXES
                      ) -> list[verify.Finding]:
    """Run the ``dist.*`` family over one stack program: derive the
    partition the optimizer would commit under a production-shaped mesh,
    collapse against the implied per-shard view, and hand both to
    :func:`repro.core.verify.check_partitions` — structural spec sanity,
    collective placement, and the per-shard VMEM refit."""
    # stack params (norm gain/bias) broadcast over rows: feature-shaped
    feat = next(iter(shapes.values()))[-1]
    param_shapes = {p: (feat,)
                    for p in partition.stack_param_names(program)}
    part = partition.plan_stack(program, shapes, param_shapes, "both", axes)
    plans: dict[int, object] = {}
    if part.active:
        shard_in = partition.shard_shapes(shapes, part.in_specs, axes)
        sdev = resource.shard_device(device, axes.n_devices)
        try:
            plans[0] = collapse.collapse(program, shard_in, sdev,
                                         itemsize=itemsize)
        except Exception as e:  # noqa: BLE001 — a lint must not crash
            return [verify.Finding(
                "dist.vmem-refit", "error", program.name,
                f"per-shard collapse failed: {type(e).__name__}: {e}")]
    pp = partition.PartitionPlan(axes=axes, partition="both",
                                 segments={0: part})
    seg = SimpleNamespace(is_stack=True, stack=program, op=None)
    cfg = SimpleNamespace(device=device, itemsize=itemsize,
                          differentiable=False)
    return verify.check_partitions([seg], plans, pp, shapes, cfg)


def lint_lm_arch(arch: str, device: resource.DeviceSpec,
                 rows: int = _ROWS) -> list[verify.Finding]:
    """Verify the stack programs an LM arch's blocks dispatch through,
    at that arch's real dimensions (bf16 sizing)."""
    from repro.configs import get_config
    from repro.layers import stacks

    cfg = get_config(arch)
    has_bias = cfg.norm == "layer"
    cases = [
        (stacks.norm_program(cfg.norm, 1e-6, has_bias),
         {"x": (rows, cfg.d_model)}),
        (stacks.addnorm_program(cfg.norm, 1e-6, has_bias),
         {"x": (rows, cfg.d_model), "res": (rows, cfg.d_model)}),
    ]
    if cfg.d_ff:
        cases.append((stacks.glu_program(cfg.act),
                      {"gate": (rows, cfg.d_ff), "up": (rows, cfg.d_ff)}))
        cases.append((stacks.act_program(cfg.act),
                      {"x": (rows, cfg.d_ff)}))
    fs: list[verify.Finding] = []
    for program, shapes in cases:
        fs.extend(lint_program(program, shapes, device, itemsize=2))
        fs.extend(lint_dist_program(program, shapes, device, itemsize=2))
    return fs


def lint_cnn(device: resource.DeviceSpec,
             input_shape: tuple[int, ...] = (1, 32, 32, 3)
             ) -> list[verify.Finding]:
    """Verify the paper's CNN domain: full VGG NetGraph (graph-level SSA +
    dead-value checks), then every nhwc stack segment through the same
    program/plan/write-model pass (f32 sizing)."""
    from repro.models import cnn

    graph, _params = cnn.vgg_net()
    segments = analyzer.analyze(graph, layout="nhwc",
                                keep=frozenset({graph.output}))
    shapes: dict[str, tuple[int, ...]] = {graph.input: input_shape}
    for seg in segments:
        if seg.is_stack:
            in_shapes = {v: shapes[v] for v in seg.stack.inputs}
            shapes.update(ir.infer_shapes(seg.stack, in_shapes))
        else:
            core_api._infer_opaque_shape(seg.op, shapes)
    fs = list(verify.check_graph(graph, shapes=shapes,
                                 keep=frozenset({graph.output})))
    for seg in segments:
        if not seg.is_stack:
            continue
        in_shapes = {v: shapes[v] for v in seg.stack.inputs}
        fs.extend(lint_program(seg.stack, in_shapes, device, itemsize=4))
    return fs


def lint_paged_kv() -> list[verify.Finding]:
    """Self-test of the ``kv.*`` block-table soundness family (the serve
    engine's paged KV cache): a consistent allocator snapshot must verify
    clean, and a seeded mutant — one shared block left writable by two
    slot tables without a copy-on-write fork — must be caught and must
    raise under ``verify='strict'``.  A checker that waves the mutant
    through is itself the lint failure."""
    fs: list[verify.Finding] = []
    clean = verify.BlockTableState(
        num_blocks=8, block_size=4,
        refcounts=(2, 1, 1, 0, 0, 0, 0, 1),
        free=(3, 4, 5, 6),
        tables=((0, 1), (0, 2)),        # block 0 is a shared prefix
        lengths=(8, 7),
        cached=(7,),
        writers=(1, 2))                 # private tails only: sound
    for f in verify.check_block_tables(clean):
        fs.append(verify.Finding(
            f.invariant, "error", "paged-kv/selftest-clean",
            f"checker flagged a consistent snapshot: {f}"))
    # seeded mutant: the shared block 0 joins the write set un-forked
    mutant = dataclasses.replace(clean, writers=(0, 1, 2))
    got = verify.check_block_tables(mutant)
    if not any(f.invariant == "kv.shared-writable" and f.severity == "error"
               for f in got):
        fs.append(verify.Finding(
            "kv.shared-writable", "error", "paged-kv/selftest-mutant",
            "seeded double-mapped writable block was not caught"))
        return fs
    try:
        verify.enforce(got, "strict", subject="paged-kv selftest")
    except verify.VerifyError:
        pass
    else:
        fs.append(verify.Finding(
            "kv.shared-writable", "error", "paged-kv/selftest-mutant",
            "strict mode did not raise on the seeded mutant"))
    return fs


def lint_dist_selftest(device: resource.DeviceSpec) -> list[verify.Finding]:
    """Self-test of the ``dist.*`` family against seeded mutants: the
    planner-derived partition of a norm stack must verify clean, while a
    tampered copy — trailing-dim shard across a feature reduction, an
    over-rank spec, a spec naming a mesh axis that does not exist, and a
    kernel spec splitting the rms reduction — must each be caught.  A
    checker that waves a mutant through is itself the lint failure."""
    from jax.sharding import PartitionSpec as P

    from repro.layers import stacks

    fs: list[verify.Finding] = []
    axes = _DIST_AXES
    program = stacks.norm_program("rms", 1e-6, False)
    shapes = {"x": (512, 256)}
    part = partition.plan_stack(
        program, shapes,
        {p: (256,) for p in partition.stack_param_names(program)},
        "both", axes)
    cfg = SimpleNamespace(device=device, itemsize=2, differentiable=False)
    seg = SimpleNamespace(is_stack=True, stack=program, op=None)

    def run(p):
        pp = partition.PartitionPlan(axes=axes, partition="both",
                                     segments={0: p})
        return verify.check_partitions([seg], {}, pp, shapes, cfg)

    if not part.active:
        fs.append(verify.Finding(
            "dist.spec-rank", "error", "dist-partition/selftest-clean",
            "planner replicated a cleanly shardable norm stack: "
            f"{part.notes}"))
    for f in run(part):
        fs.append(verify.Finding(
            f.invariant, "error", "dist-partition/selftest-clean",
            f"checker flagged a planner-derived partition: {f}"))
    mutants = [
        ("dist.collective-placement",
         dataclasses.replace(part, in_specs={"x": P("data", "model")})),
        ("dist.spec-rank",
         dataclasses.replace(part,
                             in_specs={"x": P("data", None, "model")})),
        ("dist.mesh-axis",
         dataclasses.replace(part, in_specs={"x": P("pod", None)})),
    ]
    for want, mutant in mutants:
        got = run(mutant)
        if not any(f.invariant == want and f.severity == "error"
                   for f in got):
            fs.append(verify.Finding(
                want, "error", "dist-partition/selftest-mutant",
                f"seeded {want} mutant was not caught"))
    # kernel-side fence: an rmsnorm KERNEL op whose feature dim (the rms
    # reduction) is sharded over "model" must be refused
    op = SimpleNamespace(name="rmsnorm_site", output="out",
                         attrs={"kernel": "rmsnorm",
                                "arg_shapes": ((512, 256), (256,)),
                                "out_shape": (512, 256)})
    kseg = SimpleNamespace(is_stack=False, stack=None, op=op)
    kpart = partition.SegmentPartition(
        in_specs={"arg0": P("data", "model"), "arg1": P("model")},
        out_specs={"out": P("data", "model")},
        param_specs={}, shard_shapes={}, notes=())
    pp = partition.PartitionPlan(axes=axes, partition="both",
                                 segments={0: kpart})
    got = verify.check_partitions([kseg], {}, pp, shapes, cfg)
    if not any(f.invariant == "dist.collective-placement"
               and f.severity == "error" for f in got):
        fs.append(verify.Finding(
            "dist.collective-placement", "error",
            "dist-partition/selftest-mutant",
            "seeded rmsnorm feature-dim shard was not caught"))
    return fs


def lint_serve_dist() -> list[verify.Finding]:
    """Self-test of the ``dist.serve-*`` family (the serving shard_map's
    decode-cache partition): the planner-derived plan for a dense
    qwen2.5-32b cache under the production-shaped mesh must engage both
    splits and verify clean, while seeded mutants — a pool leaf sharded
    over the batch axis, an over-rank spec, a spec naming a mesh axis that
    does not exist, and one slot leaf left replicated while the rest
    shard — must each be caught and the strict mode must raise.  A checker
    that waves a mutant through is itself the lint failure."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.models import lm

    fs: list[verify.Finding] = []
    axes = _DIST_AXES
    cfg = get_config("qwen2.5-32b").reduced()
    slots = 8
    # eval_shape only — the lint never materializes the cache
    shapes = jax.eval_shape(
        lambda: lm.init_decode_cache(cfg, slots, 64, dtype=jnp.float32))
    plan = partition.plan_decode_cache(
        shapes, "auto", axes, slots=slots,
        head_extents=(cfg.n_heads, cfg.n_kv_heads))
    if not (plan.use_data and plan.use_model):
        fs.append(verify.Finding(
            "dist.serve-slot-axis", "error", "serve-dist/selftest-clean",
            f"planner fenced a cleanly shardable dense cache: {plan.notes}"))
    for f in verify.check_decode_plan(plan):
        fs.append(verify.Finding(
            f.invariant, "error", "serve-dist/selftest-clean",
            f"checker flagged a planner-derived decode plan: {f}"))

    def mutate(field: str, **changes) -> partition.DecodeCachePlan:
        # tamper with every leaf whose path ends in `field` (e.g. the KV
        # "k" leaves of each attention layer)
        leaves = tuple(
            dataclasses.replace(leaf, **changes)
            if leaf.path.rsplit("/", 1)[-1] == field else leaf
            for leaf in plan.leaves)
        return dataclasses.replace(plan, leaves=leaves)

    k_leaf = next(leaf for leaf in plan.leaves
                  if leaf.path.rsplit("/", 1)[-1] == "k")
    rank = len(k_leaf.shape)
    mutants = [
        # the KV columns re-declared as a shared physical pool while still
        # slot-sharded: the scatter-divergence hazard
        ("dist.serve-pool-write", mutate("k", kind="pool")),
        ("dist.spec-rank", mutate("k", spec=P(*([None] * (rank + 1))))),
        ("dist.mesh-axis", mutate("k", spec=P("pod"))),
        # lengths replicated while the KV slot dims shard over "data"
        ("dist.serve-slot-axis", mutate("length", spec=P(None))),
    ]
    for want, mutant in mutants:
        got = verify.check_decode_plan(mutant)
        if not any(f.invariant == want and f.severity == "error"
                   for f in got):
            fs.append(verify.Finding(
                want, "error", "serve-dist/selftest-mutant",
                f"seeded {want} mutant was not caught"))
            continue
        try:
            verify.enforce(got, "strict", subject="serve-dist selftest")
        except verify.VerifyError:
            pass
        else:
            fs.append(verify.Finding(
                want, "error", "serve-dist/selftest-mutant",
                f"strict mode did not raise on the seeded {want} mutant"))
    return fs


def lint_arch(arch: str, device: resource.DeviceSpec,
              rows: int = _ROWS) -> list[verify.Finding]:
    if arch == "brainslug-cnn":
        return lint_cnn(device)
    if arch == "paged-kv":
        return lint_paged_kv()
    if arch == "dist-partition":
        return lint_dist_selftest(device)
    if arch == "serve-dist":
        return lint_serve_dist()
    return lint_lm_arch(arch, device, rows)


def main(argv=None) -> int:
    from repro.configs import ARCH_IDS

    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="Static verification over shipped architectures "
                    "(repro.core.verify; no kernels are executed).")
    ap.add_argument("--arch", action="append", default=None,
                    help="arch id (repeatable); default: all")
    ap.add_argument("--device", choices=sorted(_DEVICES), default="tpu_v5e")
    ap.add_argument("--rows", type=int, default=_ROWS,
                    help="row count LM stack programs are verified at")
    ap.add_argument("--out", default=None,
                    help="write the findings as JSON to this path")
    args = ap.parse_args(argv)

    archs = args.arch or [*ARCH_IDS, "brainslug-cnn", "paged-kv",
                          "dist-partition", "serve-dist"]
    device = _DEVICES[args.device]

    report: dict = {"device": device.name, "archs": {}}
    n_errors = n_warnings = 0
    for arch in archs:
        try:
            findings = lint_arch(arch, device, args.rows)
        except Exception as e:  # noqa: BLE001 — record and continue
            findings = [verify.Finding(
                "graph.shape-mismatch", "error", arch,
                f"lint crashed: {type(e).__name__}: {e}")]
        errs = verify.errors(findings)
        warns = [f for f in findings if f.severity != "error"]
        n_errors += len(errs)
        n_warnings += len(warns)
        status = "error" if errs else ("warning" if warns else "clean")
        report["archs"][arch] = {
            "status": status,
            "findings": [f.to_json() for f in findings],
        }
        print(f"[{status:>7}] {arch}: {len(errs)} error(s), "
              f"{len(warns)} warning(s)")
        for f in findings:
            print(f"    {f}")
    report["n_errors"] = n_errors
    report["n_warnings"] = n_warnings

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"report: {args.out}")
    print(f"total: {n_errors} error(s), {n_warnings} warning(s) across "
          f"{len(archs)} arch(s)")
    return 1 if n_errors else 0


if __name__ == "__main__":
    sys.exit(main())
