"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib

from repro.configs.base import (LM_SHAPES, ModelConfig, RuntimeConfig,
                                ShapeConfig, applicable_shapes)

_MODULES = {
    "mamba2-2.7b": "mamba2_2p7b",
    "deepseek-7b": "deepseek_7b",
    "qwen2.5-32b": "qwen2p5_32b",
    "qwen2.5-14b": "qwen2p5_14b",
    "minitron-8b": "minitron_8b",
    "hubert-xlarge": "hubert_xlarge",
    "paligemma-3b": "paligemma_3b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "zamba2-7b": "zamba2_7b",
    "brainslug-cnn": "brainslug_cnn",
}

ARCH_IDS = tuple(k for k in _MODULES if k != "brainslug-cnn")


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


__all__ = ["ARCH_IDS", "LM_SHAPES", "ModelConfig", "RuntimeConfig",
           "ShapeConfig", "applicable_shapes", "get_config"]
