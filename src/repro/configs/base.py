"""Config dataclasses: model architecture, input shapes, runtime execution.

Every assigned architecture is a ``ModelConfig`` instance in its own module
(``src/repro/configs/<id>.py``); the registry in ``__init__`` maps
``--arch`` ids to configs.  ``reduced()`` derives the CPU-smoke variant of
any config (same family and wiring, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm", "cnn"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int | None = None          # default d_model // n_heads
    norm: str = "rms"                  # 'rms' | 'layer'
    act: str = "silu"                  # 'silu' | 'gelu' | 'squared_relu'
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_layer_period: int = 1          # MoE every k-th layer (1 = all)
    shared_expert_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (mamba2) --------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    # --- hybrid --------------------------------------------------------------
    attn_layer_period: int = 0         # zamba2: shared attn every k layers
    # --- modality ------------------------------------------------------------
    is_encoder: bool = False
    frontend: str | None = None        # 'audio_frames' | 'vision_patches'
    n_prefix_tokens: int = 0           # vlm: image patches prepended
    frontend_dim: int = 0              # stub embedding dim fed by input_specs
    # --- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"
    source: str = ""                   # provenance note ([arXiv/hf; tier])

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder

    @property
    def subquadratic(self) -> bool:
        """Archs allowed to run the long_500k cell (assignment rule)."""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline bookkeeping)."""
        return _count_params(self, active_only=False)

    def n_active_params(self) -> int:
        return _count_params(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """CPU-smoke variant: same family/wiring, tiny dims."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 4 if self.attn_layer_period == 0
                         else 2 * max(self.attn_layer_period, 2)),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_head=32,
            d_ff=max(64, min(self.d_ff, 256)),
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            shared_expert_ff=128 if self.shared_expert_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            n_prefix_tokens=8 if self.n_prefix_tokens else 0,
            frontend_dim=64 if self.frontend_dim else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # 'train' | 'prefill' | 'decode'

    def reduced(self) -> "ShapeConfig":
        return dataclasses.replace(self, name=self.name + "-reduced",
                                   seq_len=min(self.seq_len, 64),
                                   global_batch=min(self.global_batch, 2))


LM_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> dict[str, ShapeConfig]:
    """Shape cells this arch runs, applying the assignment's skip rules:
    encoder-only archs skip decode shapes; pure full-attention archs skip
    long_500k (sub-quadratic archs run it)."""
    out = {}
    for name, sh in LM_SHAPES.items():
        if sh.kind == "decode" and not cfg.supports_decode:
            continue
        if name == "long_500k" and not cfg.subquadratic:
            continue
        if cfg.is_encoder and sh.kind == "decode":
            continue
        out[name] = sh
    return out


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Execution knobs threaded through model apply functions."""
    mode: str = "xla"                  # 'brainslug' | 'xla' | 'barrier'
    interpret: bool = True             # Pallas interpret (CPU)
    remat: str = "none"                # 'none' | 'dots' | 'full'
    # --- serving KV-cache layout ------------------------------------------
    # 'dense'  — every batch slot reserves max_len contiguous KV columns
    # 'paged'  — a fixed pool of kv_block_size-token blocks addressed
    #            through per-slot block tables (prefix sharing + COW); the
    #            continuous-batching engine allocates blocks on demand
    kv_layout: str = "dense"           # 'dense' | 'paged'
    kv_block_size: int = 16            # tokens per KV block (paged layout)
    # --- serving mesh placement -------------------------------------------
    # Which mesh axes the engine's decode-cache plan may use when a mesh is
    # passed to Server.engine(mesh=...): 'auto' takes whatever the plan can
    # shard soundly (dense slots over "data", attention heads over "model";
    # the paged pool never data-shards — replicated pools would diverge
    # under per-shard scatter writes), or restrict with 'none' | 'data' |
    # 'tensor' | 'both'.
    serve_partition: str = "auto"
    # Set by the engine *inside* its shard_map region only: the mesh axis
    # attention output projections psum over when heads are tensor-sharded.
    # None (the default everywhere else) means no collective is emitted.
    tp_axis: str | None = None
    ssd_chunk: int = 64
    decode_block_k: int = 512
    attn_block_q: int = 128
    attn_block_k: int = 128
    fused_loss_chunk: int = 0          # 0 = unchunked vocab loss
    moe_dispatch: str = "grouped"      # 'grouped' (shardable) | 'global'
    attn_impl: str = "auto"            # 'auto' | 'skip_core' (cost probes:
                                       # bypass the quadratic core so the
                                       # attention share of a block's cost
                                       # can be measured by differencing)
    # explicit dispatch-tensor layout (GSPMD replicates batched gathers
    # without it): 'tokens' keeps slots group-sharded (data axis), 'experts'
    # reshards slots expert-major (expert parallelism, all-to-all in/out);
    # 'auto' is resolved by the launcher from cfg x mesh, 'none' for raw
    # single-device use.
    moe_constraint: str = "none"
    # --- dry-run cost-fidelity knobs (XLA counts a while body ONCE, not
    # x trip-count; unrolling restores true op counts where cheap) ---------
    scan_unroll: bool = False          # unroll inner attn-chunk scans
    loss_unroll: bool = False          # unroll the chunked-loss scan


def _count_params(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    total = cfg.vocab_size * d                              # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d                         # lm head
    hd = cfg.head_dim
    attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
        + cfg.n_heads * hd * d
    dense_mlp = 3 * d * cfg.d_ff
    moe_mlp = 0
    if cfg.n_experts:
        per_expert = 3 * d * cfg.d_ff
        n_used = cfg.top_k if active_only else cfg.n_experts
        moe_mlp = n_used * per_expert + d * cfg.n_experts   # + router
        if cfg.shared_expert_ff:
            moe_mlp += 3 * d * cfg.shared_expert_ff
    ssm = 0
    if cfg.ssm_state:
        di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        ssm = d * (2 * di + 2 * n + h) + di * d \
            + cfg.ssm_conv_width * (di + 2 * n) + 3 * h
    hybrid_shared_counted = False
    for i in range(cfg.n_layers):
        if cfg.family == "ssm":
            total += ssm + d                                # + norm
        elif cfg.family == "hybrid":
            is_attn = (cfg.attn_layer_period
                       and (i + 1) % cfg.attn_layer_period == 0)
            if is_attn:
                # zamba2 SHARES one attention block across applications:
                # params counted once, FLOPs counted per application.
                if not hybrid_shared_counted and not active_only:
                    total += attn + dense_mlp + 2 * d
                    hybrid_shared_counted = True
                elif active_only:
                    total += attn + dense_mlp + 2 * d
            else:
                total += ssm + 2 * d
        elif cfg.n_experts and (i % cfg.moe_layer_period
                                == cfg.moe_layer_period - 1):
            total += attn + moe_mlp + 2 * d
        else:
            total += attn + dense_mlp + 2 * d
    return total
