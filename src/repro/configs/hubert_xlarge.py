"""hubert-xlarge — encoder-only audio transformer (w2v2 arch).
Frontend (conv feature extractor) is a stub: input_specs supplies frame
embeddings. [arXiv:2106.07447; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab_size=504, norm="layer", act="gelu",
    is_encoder=True, frontend="audio_frames", frontend_dim=512,
    source="[arXiv:2106.07447; unverified]",
)
