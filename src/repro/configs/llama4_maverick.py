"""llama4-maverick-400b-a17b — 128-expert top-1 MoE with shared expert,
MoE on alternating layers (dense otherwise), early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202048,
    n_experts=128, top_k=1, moe_layer_period=2, shared_expert_ff=8192,
    capacity_factor=1.25,
    source="[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
)
