"""paligemma-3b — SigLIP (stub) + gemma backbone VLM.
[arXiv:2407.07726; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab_size=257216, act="gelu", tie_embeddings=True,
    frontend="vision_patches", n_prefix_tokens=256, frontend_dim=1152,
    source="[arXiv:2407.07726; hf]",
)
