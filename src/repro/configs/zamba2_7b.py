"""zamba2-7b — mamba2 backbone + shared attention block every 14th layer.
[arXiv:2411.15242; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    attn_layer_period=14,
    source="[arXiv:2411.15242; unverified]",
)
