"""The paper's own evaluation domain: VGG-style CNNs with
<MaxPool, BatchNorm, ReLU> stacks (paper §5.1 synthetic benchmark and
§5.2 TorchVision families).  Used by the faithful-reproduction benchmarks,
not part of the 10 assigned LM cells."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="brainslug-cnn", family="cnn",
    n_layers=8, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=10,
    source="[paper §5; faithful]",
)
