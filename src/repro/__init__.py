"""repro: BrainSlug depth-first parallelism on TPU — JAX/Pallas framework."""

__version__ = "0.2.0"
