"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_fraction: float = 0.1):
    """Linear warmup then cosine decay to ``final_fraction * peak``."""
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        progress = jnp.clip((step - warmup_steps)
                            / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_fraction + (1 - final_fraction)
                         * 0.5 * (1 + jnp.cos(jnp.pi * progress)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule
