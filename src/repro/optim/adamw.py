"""AdamW with decoupled weight decay, global-norm clipping, bf16-safe
master accumulators — built from scratch (no optax dependency).

State layout mirrors the param tree: ``{"mu": tree, "nu": tree,
"count": scalar}`` with f32 moments regardless of param dtype, so the
optimizer is stable when params are bf16 (standard mixed-precision
practice; the f32 moments are what FSDP shards across the data axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0


def init(params: Any) -> dict:
    f32 = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(f32, params),
        "nu": jax.tree_util.tree_map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(cfg: AdamWConfig, grads: Any, state: dict, params: Any
           ) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state["count"] + 1
    lr = cfg.lr(count) if callable(cfg.lr) else jnp.asarray(cfg.lr)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def leaf(p, g, mu, nu):
        gf = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu + (1 - cfg.b1) * gf
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(gf)
        step = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) \
            if p.ndim >= 2 else 0.0          # no decay on norms/bias
        new_p = p.astype(jnp.float32) - lr * (step + decay)
        return new_p.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [leaf(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {
        "mu": tdef.unflatten([o[1] for o in out]),
        "nu": tdef.unflatten([o[2] for o in out]),
        "count": count,
    }
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
