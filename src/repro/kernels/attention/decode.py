"""Flash-decode kernel: one new query position against a long KV cache.

The decode step is memory-bound — its roofline is the KV-cache read — so
the only thing that matters is touching each cache block exactly once.  The
kernel streams ``(block_k, d)`` cache tiles through VMEM, maintains the
online softmax state in scratch, and emits the output after the last tile.
A per-batch ``length`` operand masks the unwritten tail of the cache, so
one compiled kernel serves every decode position — and because it is
per-batch, one dispatch serves a *ragged* batch of slots (the continuous-
batching engine drives every slot at its own position).

Empty-slot convention: ``lengths == 0`` (a freed / not-yet-admitted cache
slot) means the softmax is taken over zero keys.  The kernel emits exactly
zero output for such rows instead of NaN or a stale-cache average: the
running max ``m`` only leaves its -inf seed when a valid key is seen, so
finalization can mask rows whose softmax was empty.  The jnp reference
(`ref.attention_ref`) implements the same convention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(scale: float, block_k: int,
            q_ref, k_ref, v_ref, len_ref, o_ref,
            m_ref, l_ref, acc_ref) -> None:
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)             # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)             # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_idx = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_idx < len_ref[0, 0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        # m never left its NEG_INF seed <=> every key was masked (length 0).
        # The l/acc state is then exp(0)-polluted garbage; emit zeros.
        valid = m_ref[...] > NEG_INF * 0.5
        acc = jnp.where(valid, acc_ref[...], 0.0)
        o_ref[0, 0] = (acc /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _paged_kernel(scale: float, block_size: int,
                  tbl_ref, q_ref, k_ref, v_ref, len_ref, o_ref,
                  m_ref, l_ref, acc_ref) -> None:
    """Same online-softmax body as ``_kernel``; the KV tile for grid step
    ``j`` is whatever physical block the scalar-prefetched table routed in
    (see ``paged_flash_decode``'s BlockSpec index maps), and the masking
    index is the *logical* position ``j * block_size + lane``."""
    del tbl_ref                 # consumed by the BlockSpec index maps
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)             # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)             # (bs, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_idx = j * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_idx < len_ref[0, 0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        # same lengths==0 convention as the dense kernel: m still at its
        # NEG_INF seed <=> the slot attended over zero keys -> exact zeros
        valid = m_ref[...] > NEG_INF * 0.5
        acc = jnp.where(valid, acc_ref[...], 0.0)
        o_ref[0, 0] = (acc /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_flash_decode(q: jnp.ndarray, k_pool: jnp.ndarray,
                       v_pool: jnp.ndarray, table: jnp.ndarray,
                       lengths: jnp.ndarray, *, scale: float | None = None,
                       interpret: bool = True) -> jnp.ndarray:
    """Flash decode through a block table: one query position against a
    block-mapped KV pool.

    q: (B, H, 1, D); k_pool/v_pool: (N, G, block_size, D) physical blocks;
    table: (B, MB) int32 — slot ``b``'s logical block ``j`` lives in
    physical block ``table[b, j]``; lengths: (B,) int32 valid positions.

    The table rides in as a scalar-prefetch operand
    (``PrefetchScalarGridSpec``) so the KV BlockSpec index maps can gather
    ``pool[table[b, j]]`` per grid step — the kernel body never sees a
    pointer, it streams exactly the same ``(block, d)`` tiles the dense
    kernel would, just from pool rows instead of contiguous columns.  The
    tile width is the allocator's block size, so the depth-first working
    set per step is one block per head.  Unmapped table entries (the tail
    past ``ceil(length / block_size)``) may alias any pool block; their
    logical positions are ``>= length`` and masked to NEG_INF before they
    touch the softmax state.
    """
    b, h, _one, d = q.shape
    n, g, bs, _ = k_pool.shape
    mb = table.shape[1]
    rep = h // g
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    lens = lengths.reshape(b, 1).astype(jnp.int32)
    # Unmapped tail entries are masked by position before they touch the
    # softmax, but they still drive the BlockSpec index maps — clamp into
    # the pool so an allocator sentinel (e.g. ``n`` for "no block") can
    # never index out of bounds.  This is what lets the serving engine pass
    # its table operand through unfiltered.
    table = jnp.clip(table.astype(jnp.int32), 0, n - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, mb),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda b_, h_, j, tbl: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h_, j, tbl, rep=rep:
                         (tbl[b_, j], h_ // rep, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h_, j, tbl, rep=rep:
                         (tbl[b_, j], h_ // rep, 0, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, j, tbl: (b_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda b_, h_, j, tbl: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale, bs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        interpret=interpret,
    )(table, q, k_pool, v_pool, lens)
    return out


def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 lengths: jnp.ndarray, *, scale: float | None = None,
                 block_k: int = 512, interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, 1, D); k, v: (B, G, S, D); lengths: (B,) int32."""
    b, h, one, d = q.shape
    _, g, s, _ = k.shape
    rep = h // g
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    block_k = min(block_k, s)
    pk = (-s) % block_k
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else v
    lens = lengths.reshape(b, 1).astype(jnp.int32)

    grid = (b, h, (s + pk) // block_k)
    out = pl.pallas_call(
        functools.partial(_kernel, scale, block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda b_, h_, j: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, j, rep=rep: (b_, h_ // rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, j, rep=rep: (b_, h_ // rep, j, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, j: (b_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda b_, h_, j: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, kp, vp, lens)
    return out
