"""Differentiable dispatch for the attention kernels."""
from __future__ import annotations

import functools

import jax

from repro.kernels.attention import decode as decode_mod
from repro.kernels.attention import flash as flash_mod
from repro.kernels.attention import ref as ref_mod
from repro.kernels.fused_stack.ops import DispatchStats

#: Trace-time decode-dispatch counters (same snapshot/delta protocol as
#: the fused-stack STATS): which decode path a compilation took — the
#: pallas flash kernels or the jnp reference.  Recorded at the dispatch
#: sites in :mod:`repro.layers.attention`; the serve engine diffs these
#: around a run so report() can prove ``mode="brainslug"`` serving
#: actually compiled ``paged_flash_decode`` (and name the fallback
#: otherwise).
STATS = DispatchStats(keys=("decode_pallas", "decode_ref",
                            "paged_decode_pallas", "paged_decode_ref"))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True,
                    scale: float | None = None):
    """Flash forward + reference-recompute backward.  ``scale`` overrides
    the default ``1/sqrt(head_dim)`` score scaling (the kernel-registry
    path passes the scale it matched out of the traced graph)."""
    return flash_mod.flash_attention_fwd(
        q, k, v, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k, interpret=interpret)


def _fwd(q, k, v, causal, block_q, block_k, interpret, scale):
    return flash_attention(q, k, v, causal, block_q, block_k, interpret,
                           scale), (q, k, v)


def _bwd(causal, block_q, block_k, interpret, scale, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref_mod.attention_ref(q_, k_, v_, causal=causal,
                                                 scale=scale),
        q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)


def flash_decode(q, k, v, lengths, *, block_k: int = 512,
                 interpret: bool = True):
    """Inference-only (no vjp needed on the decode path)."""
    return decode_mod.flash_decode(q, k, v, lengths, block_k=block_k,
                                   interpret=interpret)


def paged_flash_decode(q, k_pool, v_pool, table, lengths, *,
                       interpret: bool = True):
    """Block-mapped flash decode (inference-only, like ``flash_decode``):
    the (B, MB) block table routes each grid step to its physical pool
    block via scalar prefetch."""
    return decode_mod.paged_flash_decode(q, k_pool, v_pool, table, lengths,
                                         interpret=interpret)
