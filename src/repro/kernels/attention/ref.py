"""Pure-jnp oracle for GQA attention (train fwd + decode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, causal: bool = True, scale: float | None = None,
                  lengths: jnp.ndarray | None = None) -> jnp.ndarray:
    """q: (B,H,Sq,D); k,v: (B,G,Sk,D); optional per-batch valid lengths.

    GQA is expressed by grouping q heads against their kv head in the
    einsum ("bgrqd,bgkd->bgrqk") instead of ``jnp.repeat``-ing k/v: the
    math is identical, but no (H/G)x-expanded copy of the KV tensor is
    ever materialized — and when H does not divide the model axis the
    expanded copy also blocks sharding (it ends up fully replicated)."""
    b, h, sq, d = q.shape
    g, sk = k.shape[1], k.shape[2]
    rep = h // g
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, g, rep, sq, d)
    # native-dtype operands with f32 accumulation: casting a 32k KV cache
    # to f32 materializes a 2x-sized copy (and adds no precision — the
    # values are already bf16-rounded)
    s = jnp.einsum("bgrqd,bgkd->bgrqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    k_idx = jnp.arange(sk)[None, None, None, None, :]
    if lengths is not None:
        s = jnp.where(k_idx < lengths[:, None, None, None, None], s,
                      NEG_INF)
    if causal:
        q_idx = jnp.arange(sq)[None, None, None, :, None]
        off = 0 if lengths is not None else sk - sq
        s = jnp.where(k_idx <= q_idx + off, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if lengths is not None:
        # Empty-softmax convention (matches the flash-decode kernel): a
        # fully-masked row — length 0, a freed continuous-batching slot —
        # attends over zero keys and outputs exactly zero, not the uniform
        # average softmax(-inf, ..., -inf) would produce.
        p = jnp.where(lengths[:, None, None, None, None] > 0, p, 0.0)
    # cast the q-side (p) down rather than the cache-side (v) up: p is the
    # smaller tensor on the decode path where v is the whole KV cache
    o = jnp.einsum("bgrqk,bgkd->bgrqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, h, sq, d).astype(q.dtype)


def decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               lengths: jnp.ndarray, *, scale: float | None = None
               ) -> jnp.ndarray:
    return attention_ref(q, k, v, causal=False, scale=scale, lengths=lengths)


def gather_paged(pool: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Materialize the per-slot view of a paged KV pool.

    pool: (N, G, bs, D) physical blocks; table: (B, MB) int32 block ids.
    Returns (B, G, MB*bs, D) — position ``p`` of slot ``b`` reads
    ``pool[table[b, p // bs], :, p % bs]``.  Unmapped table entries point
    at whatever block id the host left there (conventionally 0); their
    columns sit past the slot's ``length`` and are masked by the caller.
    """
    g = pool[table]                             # (B, MB, G, bs, D)
    b, mb, gh, bs, d = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(b, gh, mb * bs, d)


def paged_decode_ref(q: jnp.ndarray, k_pool: jnp.ndarray,
                     v_pool: jnp.ndarray, table: jnp.ndarray,
                     lengths: jnp.ndarray, *, scale: float | None = None
                     ) -> jnp.ndarray:
    """Decode oracle over a block-mapped KV pool: gather the table view,
    then the ordinary masked decode (same empty-softmax convention —
    ``lengths == 0`` rows emit exact zeros)."""
    k = gather_paged(k_pool, table)
    v = gather_paged(v_pool, table)
    return attention_ref(q, k, v, causal=False, scale=scale, lengths=lengths)
