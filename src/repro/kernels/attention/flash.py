"""Depth-first (flash) attention forward kernel for TPU.

BrainSlug's thesis — push a cache-resident tile through the *whole* op chain
instead of materializing every layer — is exactly the flash-attention
schedule: the ``(block_q, block_k)`` score tile never leaves VMEM; the
softmax chain (scale → mask → max → exp → normalize → weight) is applied
depth-first with an online rescaling, so the O(S²) score matrix is never
written to HBM.

Grid: ``(batch, q_heads, num_q_blocks, num_k_blocks)`` with the k-block axis
innermost (sequential on TPU), carrying the running max / denominator /
accumulator in VMEM scratch across k blocks.  GQA maps q head ``h`` onto KV
head ``h // (H // G)`` in the k/v index_maps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(scale: float, causal: bool, block_q: int, block_k: int,
            seq_k: int, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref) -> None:
    i = pl.program_id(2)
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    k_idx = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = k_idx < seq_k                           # padded tail of K
    if causal:
        q_idx = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        valid = valid & (k_idx <= q_idx)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                             # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, scale: float | None = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, Sq, D); k, v: (B, G, Sk, D) with H a multiple of G."""
    b, h, sq, d = q.shape
    _, g, sk, _ = k.shape
    if h % g:
        raise ValueError(f"q heads {h} not a multiple of kv heads {g}")
    rep = h // g
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else v

    grid = (b, h, (sq + pq) // block_q, (sk + pk) // block_k)

    out = pl.pallas_call(
        functools.partial(_kernel, scale, causal, block_q, block_k, sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, i, j, rep=rep: (b_, h_ // rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, i, j, rep=rep: (b_, h_ // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq + pq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :sq, :]
