"""Fused residual-add + RMSNorm Pallas kernel.

The highest-frequency BrainSlug stack instance in the LM families:
``h = x + residual; y = rmsnorm(h) * scale``.  Depth-first: each
``(block_rows, D)`` tile is read once, the add, the row reduction and the
normalization all happen while the tile is VMEM-resident, and both outputs
(normalized value + new residual stream) are written once.  Breadth-first
execution would round-trip ``h`` through HBM between the add and the norm.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(eps: float, has_residual: bool, x_ref, *refs) -> None:
    if has_residual:
        res_ref, scale_ref, y_ref, h_ref = refs
        h = x_ref[...] + res_ref[...]
        h_ref[...] = h
    else:
        (scale_ref, y_ref) = refs
        h = x_ref[...]
    hf = h.astype(jnp.float32)
    var = jnp.mean(jnp.square(hf), axis=-1, keepdims=True)
    y = hf * jax.lax.rsqrt(var + eps)
    y_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(h.dtype)


def rmsnorm_fwd(x: jnp.ndarray,
                scale: jnp.ndarray,
                residual: jnp.ndarray | None = None,
                *,
                eps: float = 1e-6,
                block_rows: int = 256,
                interpret: bool = True):
    """Returns ``(y, h)`` where ``h = x (+ residual)`` is the new residual
    stream and ``y = rmsnorm(h) * scale``."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    rows = 1
    for s in lead:
        rows *= s
    xf = x.reshape(rows, d)
    has_res = residual is not None
    rf = residual.reshape(rows, d) if has_res else None

    block_rows = min(block_rows, max(rows, 1))
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        if has_res:
            rf = jnp.pad(rf, ((0, pad), (0, 0)))
    n = (rows + pad) // block_rows

    tile = pl.BlockSpec((block_rows, d), lambda i: (i, 0))
    pspec = pl.BlockSpec((1, d), lambda i: (0, 0))
    out_shape = [jax.ShapeDtypeStruct(((rows + pad), d), x.dtype)]
    out_specs = [tile]
    operands = [xf]
    in_specs = [tile]
    if has_res:
        operands.append(rf)
        in_specs.append(tile)
        out_shape.append(jax.ShapeDtypeStruct(((rows + pad), d), x.dtype))
        out_specs.append(tile)
    operands.append(scale.reshape(1, d))
    in_specs.append(pspec)

    outs = pl.pallas_call(
        functools.partial(_kernel, eps, has_res),
        grid=(n,),
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        interpret=interpret,
    )(*operands)
    if not isinstance(outs, (list, tuple)):
        outs = (outs,)
    y = outs[0][:rows].reshape(*lead, d)
    h = outs[1][:rows].reshape(*lead, d) if has_res else x
    return y, h
