"""Differentiable dispatch for fused residual+RMSNorm."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm import ref as ref_mod
from repro.kernels.rmsnorm import rmsnorm as kernel_mod


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def rmsnorm(x, scale, residual=None, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = True):
    return kernel_mod.rmsnorm_fwd(x, scale, residual, eps=eps,
                                  block_rows=block_rows, interpret=interpret)


def _fwd(x, scale, residual, eps, block_rows, interpret):
    out = rmsnorm(x, scale, residual, eps, block_rows, interpret)
    return out, (x, scale, residual)


def _bwd(eps, block_rows, interpret, res, g):
    x, scale, residual = res
    if residual is None:
        def f(x_, s_):
            return ref_mod.rmsnorm_ref(x_, s_, None, eps=eps)
        _, vjp = jax.vjp(f, x, scale)
        dx, ds = vjp(g)
        return dx, ds, None
    def f(x_, s_, r_):
        return ref_mod.rmsnorm_ref(x_, s_, r_, eps=eps)
    _, vjp = jax.vjp(f, x, scale, residual)
    return vjp(g)


rmsnorm.defvjp(_fwd, _bwd)


def rmsnorm_value(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
                  interpret: bool = True):
    """Normalized value only (no residual stream) — the kernel-registry
    entry point for the traced ``x * rsqrt(mean(x^2) + eps) * g`` idiom."""
    y, _ = rmsnorm(x, scale, None, eps, block_rows, interpret)
    return y
