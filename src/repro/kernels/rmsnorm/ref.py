"""Pure-jnp oracle for fused residual+RMSNorm."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray,
                residual: jnp.ndarray | None = None,
                *, eps: float = 1e-6):
    h = x + residual if residual is not None else x
    hf = h.astype(jnp.float32)
    var = jnp.mean(jnp.square(hf), axis=-1, keepdims=True)
    y = (hf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
         ).astype(h.dtype)
    return y, h
