"""Pallas TPU kernels.

Layout per kernel family: ``<name>.py`` holds the ``pl.pallas_call`` +
BlockSpec implementation, ``ops.py``-level wrappers (jit + custom_vjp) live
next to it, and ``ref.py`` is the pure-jnp oracle tests compare against.

All kernels are written for TPU (VMEM BlockSpec tiling, (8,128) alignment,
MXU-sized matmul tiles) and validated on CPU via ``interpret=True``.
"""
