"""Generated depth-first kernel for rows-layout stacks (LM chains).

One ``pl.pallas_call`` executes an entire collapsed Sequence on a
``(tile_rows, features)`` VMEM tile: the tile is read from HBM once, every
op of the sequence is applied while it is VMEM/VREG-resident, and the result
is written back once.  This is the paper's depth-first schedule with VMEM
playing the role of the L1/shared-memory cache.

The kernel *body* is the shared IR interpreter (:func:`repro.core.ir.apply_op`)
traced over the tile values — the same semantics object that defines the
reference path, so the generated kernel cannot drift from the oracle.

The backward twin lives in :mod:`repro.kernels.fused_stack.rows_bwd` and
shares this module's flatten/pad/param plumbing.
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import ir


def row_block_index(i):
    """Output/input BlockSpec index map for ``(tile_rows, F)`` tiles: grid
    cell ``i`` owns row-block ``i``.  Module-level (not a lambda) so the
    static verifier's write model (:func:`write_model`) evaluates the
    *same* function the ``pallas_call`` BlockSpecs install — the race
    check cannot drift from the kernel."""
    return (i, 0)


def shared_block_index(i):
    """BlockSpec index map for ``(1, F)`` parameter blocks: every grid
    cell addresses the single shared block."""
    del i
    return (0, 0)


def write_model(program: ir.StackProgram,
                shapes: Mapping[str, tuple[int, ...]],
                tile_rows: int, padded_rows: int) -> list[dict]:
    """The forward kernel's output-write geometry, as data: one entry per
    program output with the grid-evaluable index map, block shape, and
    destination array shape :func:`fused_rows_call` will use.  Consumed by
    ``repro.core.verify`` to prove pairwise-disjoint writes."""
    models = []
    for name in program.outputs:
        f = shapes[name][-1]
        models.append({
            "name": name, "block_shape": (tile_rows, f),
            "index_map": row_block_index,
            "array_shape": (padded_rows, f), "accumulate": None})
    return models


def _kernel(program: ir.StackProgram, n_inputs: int, n_params: int,
            *refs) -> None:
    in_refs = refs[:n_inputs]
    param_refs = refs[n_inputs:n_inputs + n_params]
    out_refs = refs[n_inputs + n_params:]

    env = {name: ref[...] for name, ref in zip(program.inputs, in_refs)}
    # Params keep their (1, F) block shape; broadcasting against the
    # (tile_rows, F) tiles is free and avoids 1-D operands on TPU.
    params = {name: ref[...] for name, ref in
              zip(program.param_names, param_refs)}
    for op in program.ops:
        env[op.output] = ir.apply_op(op, env, params)
    for name, ref in zip(program.outputs, out_refs):
        ref[...] = env[name]


def flatten_rows(prog_name: str, names: list[str],
                 values: Mapping[str, jnp.ndarray], tile_rows: int
                 ) -> tuple[list[jnp.ndarray], tuple[int, ...], int, int]:
    """Flatten the named values to ``(rows, F)`` and zero-pad the row
    dimension to a ``tile_rows`` multiple.  Returns
    (flat arrays, lead shape, rows, pad)."""
    arrays = [values[n] for n in names]
    lead = arrays[0].shape[:-1]
    for n, a in zip(names, arrays):
        if a.shape[:-1] != lead:
            raise ValueError(f"{prog_name}: value {n} leading shape "
                             f"{a.shape[:-1]} != {lead}")
    rows = 1
    for d in lead:
        rows *= d
    flat = [a.reshape(rows, a.shape[-1]) for a in arrays]
    pad = (-rows) % tile_rows
    if pad:
        flat = [jnp.pad(a, ((0, pad), (0, 0))) for a in flat]
    return flat, lead, rows, pad


def prep_params(program: ir.StackProgram,
                params: Mapping[str, jnp.ndarray]) -> list[jnp.ndarray]:
    """Reshape per-feature parameter vectors to (1, F) 2-D operands."""
    pvals = []
    for p in program.param_names:
        v = jnp.asarray(params[p])
        pvals.append(v.reshape(1, -1) if v.ndim <= 1
                     else v.reshape(1, v.shape[-1]))
    return pvals


def fused_rows_call(program: ir.StackProgram,
                    inputs: Mapping[str, jnp.ndarray],
                    params: Mapping[str, jnp.ndarray],
                    *,
                    tile_rows: int = 256,
                    interpret: bool = True) -> dict[str, jnp.ndarray]:
    """Run a rows-layout sequence as one fused Pallas kernel.

    Every input must share the same leading shape ``(..., F_i)``; leading
    dims are flattened to a row dimension that is tiled by ``tile_rows``.
    Parameters are per-feature vectors (or scalars) held fully in VMEM.
    """
    names = list(program.inputs)
    flat, lead, rows, pad = flatten_rows(program.name, names, inputs,
                                         tile_rows)
    padded_rows = rows + pad
    grid = (padded_rows // tile_rows,)

    pnames = list(program.param_names)
    pvals = prep_params(program, params)

    # Infer output shapes/dtypes from the interpreter on ShapeDtypeStructs.
    out_shapes = _infer_outputs(program, flat, names, pnames, pvals)

    in_specs = [pl.BlockSpec((tile_rows, a.shape[-1]), row_block_index)
                for a in flat]
    in_specs += [pl.BlockSpec((1, v.shape[-1]), shared_block_index)
                 for v in pvals]
    out_specs = [pl.BlockSpec((tile_rows, s.shape[-1]), row_block_index)
                 for s in out_shapes]

    fn = pl.pallas_call(
        functools.partial(_kernel, program, len(flat), len(pvals)),
        grid=grid,
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shapes),
        interpret=interpret,
    )
    outs = fn(*flat, *pvals)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    result = {}
    for name, o in zip(program.outputs, outs):
        o = o[:rows] if pad else o
        result[name] = o.reshape(*lead, o.shape[-1])
    return result


def _infer_outputs(program: ir.StackProgram, flat, names, pnames, pvals):
    def run(*args):
        env = dict(zip(names, args[: len(names)]))
        ps = dict(zip(pnames, args[len(names):]))
        out = ir.run_program(program, env, ps)
        return tuple(out[v] for v in program.outputs)

    shapes = jax.eval_shape(run, *flat, *pvals)
    return [jax.ShapeDtypeStruct(s.shape, s.dtype) for s in shapes]
