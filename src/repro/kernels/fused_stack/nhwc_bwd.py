"""Generated depth-first **backward** kernel for nhwc-layout stacks.

The forward kernel (:mod:`repro.kernels.fused_stack.nhwc`) produces one
``(tile_out_h, tile_out_w, C)`` output patch per grid cell from a halo-grown
input region held in VMEM.  This module generates the training twin: one
``pl.pallas_call`` over the same ``(N, tiles_h, tiles_w)`` grid that

1. *recomputes* the op chain on the halo-grown patch (via the forward's own
   :func:`~repro.kernels.fused_stack.nhwc.run_tile` — one halo/mask
   semantics for both kernels),
2. runs the per-op VJP rules of :mod:`repro.core.autodiff` in reverse while
   every level is still VMEM-resident — max-pool cotangents routed to the
   first maximal window position (the jax/XLA tie convention), avg-pool
   cotangents scattered uniformly,
3. applies the *masking dual* of the forward's −inf/0 neutral elements:
   the cotangent of each op output is zeroed outside the true image at its
   level, and each pool's input cotangent is zeroed where the forward
   substituted the neutral element — so out-of-image halo positions
   contribute exactly zero gradient, and
4. writes one halo-extent input-cotangent patch per grid cell, while
   accumulating parameter (and broadcast-extra) gradients across the grid
   into shared ``(1, C)`` blocks (sequential TPU grid ⇒ race-free
   grid-sum, the rows_bwd epilogue pattern).

Overlap-add
-----------
Neighbouring tiles read *overlapping* halo regions in the forward, so their
input-cotangent patches overlap too and must be **summed**.  The kernel
writes each tile's patch to its own slot; the wrapper performs the
overlap-add with a ``fori_loop`` of dynamic-slice accumulates (tile origins
are affine in the grid index, and the trace stays O(1) in tile count) and
then crops the pre-padding — which also drops any garbage cotangent the
recompute produced at out-of-image positions of the input level.
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import autodiff, ir
from repro.kernels.fused_stack import nhwc


def patch_block_index(i, j, k):
    """Input-cotangent BlockSpec index map: grid cell ``(n, i, j)`` owns
    its private ``(1, 1, 1, eh, ew, C)`` patch slot.  Module-level so the
    static verifier's write model evaluates the same function the
    ``pallas_call`` BlockSpec installs."""
    return (i, j, k, 0, 0, 0)


def write_model(program: ir.StackProgram, grid: tuple[int, int, int],
                eh: int, ew: int, c: int) -> list[dict]:
    """The backward kernel's output-write geometry, as data, for the
    static verifier: per-cell private patch slots (the halo overlap-add
    idiom, ``accumulate='overlap-slot'`` — disjoint slot writes; the
    wrapper sums the logical overlaps outside the kernel) plus shared
    ``(1, C)`` grid-sum accumulators for broadcast extras and params."""
    n, gh, gw = grid
    models = [{
        "name": "dx_patches", "block_shape": (1, 1, 1, eh, ew, c),
        "index_map": patch_block_index,
        "array_shape": (n, gh, gw, eh, ew, c),
        "accumulate": "overlap-slot"}]
    for name in (*program.inputs[1:], *program.param_names):
        models.append({
            "name": f"acc:{name}", "block_shape": (1, c),
            "index_map": nhwc.shared_block_index,
            "array_shape": (1, c), "accumulate": "grid-sum"})
    return models


def _bwd_kernel(program: ir.StackProgram, levels, pad_off_h: int,
                pad_off_w: int, n_extra: int, n_params: int, *refs) -> None:
    src_ref = refs[0]
    extra_refs = refs[1: 1 + n_extra]
    param_refs = refs[1 + n_extra: 1 + n_extra + n_params]
    g_ref = refs[1 + n_extra + n_params]
    dx_ref = refs[2 + n_extra + n_params]
    dextra_refs = refs[3 + n_extra + n_params: 3 + 2 * n_extra + n_params]
    dparam_refs = refs[3 + 2 * n_extra + n_params:]

    n = pl.program_id(0)
    pi = pl.program_id(1)
    pj = pl.program_id(2)

    lv0 = levels[0]
    out_lv = levels[-1]
    g0h = pi * out_lv.extent_h * lv0.mul_h - lv0.off_h
    g0w = pj * out_lv.extent_w * lv0.mul_w - lv0.off_w
    buf = src_ref[n, pl.dslice(g0h + pad_off_h, lv0.extent_h),
                  pl.dslice(g0w + pad_off_w, lv0.extent_w), :]

    extra_names = list(program.inputs[1:])
    extras = {name: ref[...][None] for name, ref in
              zip(extra_names, extra_refs)}
    params = {name: ref[...] for name, ref in
              zip(program.param_names, param_refs)}

    # (1) depth-first recompute — the forward kernel's own tile function.
    env, origins, masked, valids = nhwc.run_tile(
        program, levels, buf, extras, params, g0h, g0w)

    # (2) reverse sweep.  The incoming cotangent tile is zero on grid-padded
    # output rows/cols (the wrapper zero-pads g), and every op's output
    # cotangent is re-zeroed against that level's validity mask before use:
    # positions outside the true image recompute garbage primals, and a
    # 0 * inf slipping through an elementwise rule would otherwise scatter
    # NaNs into valid input positions via the pool routing.
    cot: dict[str, jnp.ndarray] = {program.outputs[0]: g_ref[0]}
    dparams: dict[str, jnp.ndarray] = {}
    for i in reversed(range(len(program.ops))):
        op = program.ops[i]
        g = cot.pop(op.output, None)
        if g is None:                       # output never used downstream
            continue
        valid_out = nhwc.tile_valid(g.shape[:2], origins[op.output],
                                    levels[i + 1])
        g = jnp.where(valid_out, g, 0)
        if op.kind == ir.OpKind.POOL2D:
            dx = autodiff.pool2d_patch_vjp(op, masked[op.name],
                                           env[op.output], g)
            # masking dual: the forward replaced out-of-image positions with
            # the neutral element, so their cotangent is exactly zero.
            dx = jnp.where(valids[op.name], dx, 0)
            v = op.inputs[0]
            cot[v] = cot[v] + dx if v in cot else dx
            continue
        din, dp = autodiff.op_vjp(op, env, params, g, row_mask=valid_out)
        for v, d in din.items():
            cot[v] = cot[v] + d if v in cot else d
        for p, d in dp.items():
            dparams[p] = dparams[p] + d if p in dparams else d

    # (3) input cotangent: one halo-extent patch per grid cell; the wrapper
    # overlap-adds across tiles.
    primary = program.inputs[0]
    dx0 = cot.get(primary)
    if dx0 is None:
        dx0 = jnp.zeros(buf.shape, buf.dtype)
    dx_ref[...] = dx0.astype(buf.dtype)[None, None, None]

    # (4) parameter / broadcast-extra gradients: zero-init on the first grid
    # cell, then every cell accumulates its (1, C) partial into the shared
    # block (sequential grid ⇒ race-free reduction).
    if dextra_refs or dparam_refs:
        @pl.when((n == 0) & (pi == 0) & (pj == 0))
        def _init():
            for ref in (*dextra_refs, *dparam_refs):
                ref[...] = jnp.zeros(ref.shape, ref.dtype)

        for name, ref in zip(extra_names, dextra_refs):
            d = cot.get(name)
            if d is None:
                continue
            ref[...] += d.reshape(1, -1).astype(ref.dtype)
        for pname, ref in zip(program.param_names, dparam_refs):
            d = dparams.get(pname)
            if d is None:
                continue
            ref[...] += d.reshape(1, -1).astype(ref.dtype)


def fused_nhwc_bwd_call(program: ir.StackProgram,
                        x: jnp.ndarray,
                        extras: Mapping[str, jnp.ndarray],
                        params: Mapping[str, jnp.ndarray],
                        g: jnp.ndarray,
                        *,
                        tile_out_h: int = 8,
                        tile_out_w: int = 8,
                        interpret: bool = True
                        ) -> tuple[jnp.ndarray, dict[str, jnp.ndarray],
                                   dict[str, jnp.ndarray]]:
    """Run the generated recompute-in-tile backward for one nhwc sequence.

    ``g`` is the cotangent of the single program output; ``extras`` the
    broadcast side operands (``program.inputs[1:]``).  Returns
    ``(dx, dextras, dparams)`` with shapes/dtypes matching the primals.
    """
    extras = dict(extras or {})
    n, h, w, c = x.shape
    (levels, grid, xp, (left_h, left_w), (oh, ow), (pad_oh, pad_ow),
     (th, tw)) = nhwc.plan_geometry(program, x, extras, tile_out_h,
                                    tile_out_w)
    lv0 = levels[0]
    eh, ew = lv0.extent_h, lv0.extent_w

    # zero-pad the cotangent over the grid-padding region: padded output
    # positions contribute no gradient.
    gp = jnp.pad(g, ((0, 0), (0, pad_oh), (0, pad_ow), (0, 0)))

    evals = nhwc.prep_extras(program, extras)
    pnames = list(program.param_names)
    pvals = [jnp.asarray(params[p]).reshape(1, -1) for p in pnames]

    in_specs = [pl.BlockSpec(memory_space=pl.ANY)]
    in_specs += [pl.BlockSpec((1, v.shape[-1]), nhwc.shared_block_index)
                 for v in evals + pvals]
    in_specs += [pl.BlockSpec((1, th, tw, c), nhwc.out_block_index)]

    out_shapes = [jax.ShapeDtypeStruct((n, grid[1], grid[2], eh, ew, c),
                                       x.dtype)]
    out_specs = [pl.BlockSpec((1, 1, 1, eh, ew, c), patch_block_index)]
    # grid-summed accumulators: every cell addresses block (0, 0)
    for v in evals + pvals:
        out_shapes.append(jax.ShapeDtypeStruct(v.shape, v.dtype))
        out_specs.append(pl.BlockSpec((1, v.shape[-1]),
                                      nhwc.shared_block_index))

    fn = pl.pallas_call(
        functools.partial(_bwd_kernel, program, levels, left_h, left_w,
                          len(evals), len(pvals)),
        grid=grid,
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shapes),
        interpret=interpret,
    )
    outs = fn(xp, *evals, *pvals, gp)
    patches = outs[0]

    # Overlap-add: neighbouring tiles' halo patches overlap and must be
    # summed.  Tile origins are affine in the grid index, so a fori_loop
    # keeps the trace O(1) in tile count (a statically unrolled chain would
    # bake tiles_h * tiles_w update ops into every backward jaxpr).
    gh, gw = grid[1], grid[2]

    def _accumulate(t, acc):
        pi = t // gw
        pj = t % gw
        h0 = pi * th * lv0.mul_h - lv0.off_h + left_h
        w0 = pj * tw * lv0.mul_w - lv0.off_w + left_w
        patch = jax.lax.dynamic_slice(
            patches, (0, pi, pj, 0, 0, 0), (n, 1, 1, eh, ew, c))[:, 0, 0]
        cur = jax.lax.dynamic_slice(acc, (0, h0, w0, 0), (n, eh, ew, c))
        return jax.lax.dynamic_update_slice(acc, cur + patch,
                                            (0, h0, w0, 0))

    dxp = jax.lax.fori_loop(0, gh * gw, _accumulate, jnp.zeros_like(xp))
    dx = dxp[:, left_h: left_h + h, left_w: left_w + w, :]

    dextras: dict[str, jnp.ndarray] = {}
    for name, d in zip(program.inputs[1:], outs[1: 1 + len(evals)]):
        dextras[name] = d.reshape(jnp.shape(extras[name])).astype(
            jnp.asarray(extras[name]).dtype)
    dparams: dict[str, jnp.ndarray] = {}
    for pname, d in zip(pnames, outs[1 + len(evals):]):
        dparams[pname] = d.reshape(jnp.shape(params[pname]))
    return dx, dextras, dparams
