"""Pure-jnp oracle for the fused stack kernels.

The oracle *is* the IR interpreter run breadth-first — semantically identical
to PyTorch layer-by-layer execution of the same stack.
"""
from __future__ import annotations

from typing import Mapping

import jax.numpy as jnp

from repro.core import ir


def fused_stack_ref(program: ir.StackProgram,
                    inputs: Mapping[str, jnp.ndarray],
                    params: Mapping[str, jnp.ndarray],
                    *,
                    barrier: bool = False) -> dict[str, jnp.ndarray]:
    return ir.run_program(program, inputs, params, barrier=barrier)
