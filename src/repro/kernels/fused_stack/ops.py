"""Jitted wrappers for the generated fused-stack kernels.

``fused_stack_apply`` dispatches one collapsed Sequence:

* mode ``brainslug``  — the generated Pallas kernel (depth-first schedule).
  Training works through ``jax.custom_vjp``: forward runs the kernel,
  backward recomputes through the reference interpreter (fusion changes the
  schedule, not the math, so the reference VJP is exact).
* mode ``xla``        — jit of the interpreter (XLA fuses what it can).
* mode ``barrier``    — per-op ``optimization_barrier`` (paper's
  breadth-first baseline; every intermediate is materialized).
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.core import ir
from repro.kernels.fused_stack import nhwc, ref, rows

MODES = ("brainslug", "xla", "barrier")


def fused_stack_apply(program: ir.StackProgram,
                      inputs: Mapping[str, jnp.ndarray],
                      params: Mapping[str, jnp.ndarray],
                      *,
                      mode: str = "xla",
                      tile_rows: int = 256,
                      tile_out_h: int = 8,
                      tile_out_w: int = 8,
                      interpret: bool = True) -> dict[str, jnp.ndarray]:
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if mode == "barrier":
        return ref.fused_stack_ref(program, inputs, params, barrier=True)
    if mode == "xla":
        return ref.fused_stack_ref(program, inputs, params)

    # mode == 'brainslug': differentiable Pallas dispatch.
    names = tuple(program.inputs)
    pnames = tuple(program.param_names)
    in_list = tuple(inputs[n] for n in names)
    p_list = tuple(params[p] for p in pnames)
    outs = _pallas_diff(program, names, pnames, tile_rows, tile_out_h,
                        tile_out_w, interpret, in_list, p_list)
    return dict(zip(program.outputs, outs))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5, 6))
def _pallas_diff(program, names, pnames, tile_rows, th, tw, interpret,
                 in_list, p_list):
    inputs = dict(zip(names, in_list))
    params = dict(zip(pnames, p_list))
    if program.layout == "rows" or len(names) > 1:
        if program.layout == "nhwc":
            # multi-input nhwc stacks fall back to the XLA path (documented)
            out = ref.fused_stack_ref(program, inputs, params)
            return tuple(out[v] for v in program.outputs)
        out = rows.fused_rows_call(program, inputs, params,
                                   tile_rows=tile_rows, interpret=interpret)
        return tuple(out[v] for v in program.outputs)
    y = nhwc.fused_nhwc_call(program, inputs[names[0]], params,
                             tile_out_h=th, tile_out_w=tw,
                             interpret=interpret)
    return (y,)


def _fwd(program, names, pnames, tile_rows, th, tw, interpret,
         in_list, p_list):
    outs = _pallas_diff(program, names, pnames, tile_rows, th, tw, interpret,
                        in_list, p_list)
    return outs, (in_list, p_list)


def _bwd(program, names, pnames, tile_rows, th, tw, interpret, res, g):
    in_list, p_list = res

    def reference(ins, ps):
        out = ref.fused_stack_ref(program, dict(zip(names, ins)),
                                  dict(zip(pnames, ps)))
        return tuple(out[v] for v in program.outputs)

    _, vjp = jax.vjp(reference, in_list, p_list)
    din, dp = vjp(tuple(g))
    return din, dp


_pallas_diff.defvjp(_fwd, _bwd)
