"""Jitted wrappers for the generated fused-stack kernels.

``fused_stack_apply`` dispatches one collapsed Sequence:

* mode ``brainslug``  — the generated Pallas kernels (depth-first schedule).
  Training runs depth-first end to end: the forward kernel keeps the tile
  VMEM-resident through the op chain, and the generated backward kernels
  (:mod:`repro.kernels.fused_stack.rows_bwd` for rows-layout chains,
  :mod:`repro.kernels.fused_stack.nhwc_bwd` for pooling stacks) recompute
  the chain on the resident tile and apply the per-op VJP rules of
  :mod:`repro.core.autodiff` in reverse — no reference-interpreter dispatch
  on either hot path.  nhwc stacks whose extra inputs are broadcast side
  operands (every non-channel dim 1) run generated too; only
  spatially-extended multi-input nhwc stacks keep the reference VJP
  (fusion changes the schedule, not the math, so the reference is exact).
* mode ``xla``        — jit of the interpreter (XLA fuses what it can).
* mode ``barrier``    — per-op ``optimization_barrier`` (paper's
  breadth-first baseline; every intermediate is materialized).

Executables are built once per structural signature + tile geometry and
cached (paper: "If there are multiple equivalent stacks, BRAINSLUG only
generates the code once") — one cache entry holds *both* the forward and the
backward kernel closure.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Mapping

import jax
import jax.numpy as jnp

from repro.core import autodiff, ir
from repro.kernels.fused_stack import nhwc, nhwc_bwd, ref, rows, rows_bwd

MODES = ("brainslug", "xla", "barrier")


class DispatchStats:
    """Trace-time dispatch counters (the mode stat the acceptance criteria
    ask for): which path ran — the generated depth-first kernel or the
    reference-interpreter fallback.  Counts are incremented when the path is
    *traced*, i.e. once per compilation, which is exactly the "was the
    generated kernel used" question.

    The instance is a process-global singleton (``STATS``); callers that
    need isolation take a :meth:`snapshot` first and diff against it
    (``STATS.delta(before)``) instead of asserting absolute counts —
    benchmark drivers additionally :meth:`reset` at phase boundaries so
    counts do not bleed across runs.

    The class is key-set agnostic so other dispatch surfaces can reuse the
    snapshot/delta protocol: the serving drivers instantiate their own
    counters (``repro.launch.serve.STATS`` / ``repro.launch.engine.STATS``)
    with *runtime* dispatch keys — there the counts are per call, not per
    trace, because "how many decode dispatches did the loop issue" is the
    question those counters answer."""

    BASE_KEYS = ("fwd_generated", "fwd_reference",
                 "bwd_generated", "bwd_reference")

    def __init__(self, keys: tuple[str, ...] = BASE_KEYS) -> None:
        self._keys = tuple(keys)
        self.reset()

    def reset(self) -> None:
        self.counts: dict[str, int] = {k: 0 for k in self._keys}

    def record(self, key: str, n: int = 1) -> None:
        if key not in self.counts:
            raise KeyError(
                f"unknown dispatch counter {key!r}; declared: {self._keys}")
        self.counts[key] += n

    def snapshot(self) -> dict[str, int]:
        """An immutable copy of the current counts, for later diffing."""
        return dict(self.counts)

    def delta(self, before: Mapping[str, int]) -> dict[str, int]:
        """Counts recorded since ``before`` (a :meth:`snapshot`)."""
        return {k: v - before.get(k, 0) for k, v in self.counts.items()}


STATS = DispatchStats()


def is_broadcast_operand(a) -> bool:
    """True when an nhwc side operand can ride along like a parameter: a
    channel vector, or any shape whose every non-channel dim is 1."""
    shape = jnp.shape(a)
    if len(shape) == 0:
        return False                    # scalars: keep the reference path
    return len(shape) == 1 or all(d == 1 for d in shape[:-1])


@dataclasses.dataclass(frozen=True)
class FusedExecutable:
    """One generated forward+backward pair for a Sequence (brainslug mode)."""

    program: ir.StackProgram
    tile_rows: int
    tile_out_h: int
    tile_out_w: int
    interpret: bool
    call: Callable[..., tuple[jnp.ndarray, ...]]   # (in_list, p_list) -> outs
    generated_bwd: bool                            # depth-first backward?


#: LRU over generated forward+backward pairs.  Bounded: a long-lived
#: serve process that keeps producing fresh shape signatures must not
#: leak one executable per signature (``set_cache_limit`` is driven by
#: ``OptimizeConfig.code_cache_size`` through the codegen layer).
_EXEC_CACHE: "OrderedDict[tuple, FusedExecutable]" = OrderedDict()
_CACHE_LIMIT = 256


def set_cache_limit(n: int) -> None:
    global _CACHE_LIMIT
    if n < 1:
        raise ValueError(f"cache limit must be >= 1, got {n}")
    _CACHE_LIMIT = n
    while len(_EXEC_CACHE) > _CACHE_LIMIT:
        _EXEC_CACHE.popitem(last=False)


def get_executable(program: ir.StackProgram, *, tile_rows: int = 256,
                   tile_out_h: int = 8, tile_out_w: int = 8,
                   interpret: bool = True) -> FusedExecutable:
    """Build (or fetch) the cached forward+backward executable for
    ``program`` at the given tile geometry, keyed on the structural
    signature so equivalent stacks share one generated pair."""
    key = (program.signature(), tile_rows, tile_out_h, tile_out_w, interpret)
    exe = _EXEC_CACHE.get(key)
    if exe is None:
        exe = _build_executable(program, tile_rows, tile_out_h, tile_out_w,
                                interpret)
        _EXEC_CACHE[key] = exe
    _EXEC_CACHE.move_to_end(key)
    while len(_EXEC_CACHE) > _CACHE_LIMIT:
        _EXEC_CACHE.popitem(last=False)
    return exe


def clear_executable_cache() -> None:
    _EXEC_CACHE.clear()


def _build_executable(program: ir.StackProgram, tile_rows: int,
                      tile_out_h: int, tile_out_w: int,
                      interpret: bool) -> FusedExecutable:
    names = tuple(program.inputs)
    pnames = tuple(program.param_names)
    is_nhwc = program.layout == "nhwc"
    diffable = autodiff.supports(program)
    generated_bwd = diffable and (not is_nhwc or len(program.outputs) == 1)

    def _nhwc_generated(in_list) -> bool:
        """Can this call run the generated nhwc kernels?  Shape-dependent:
        extra inputs must be broadcast side operands."""
        return (len(program.outputs) == 1
                and all(is_broadcast_operand(a) for a in in_list[1:]))

    def _forward(in_list, p_list):
        inputs = dict(zip(names, in_list))
        params = dict(zip(pnames, p_list))
        if is_nhwc:
            if _nhwc_generated(in_list):
                STATS.record("fwd_generated")
                y = nhwc.fused_nhwc_call(
                    program, in_list[0], params,
                    extras=dict(zip(names[1:], in_list[1:])),
                    tile_out_h=tile_out_h, tile_out_w=tile_out_w,
                    interpret=interpret)
                return (y,)
            # spatially-extended multi-input nhwc: XLA-path fallback
            STATS.record("fwd_reference")
            out = ref.fused_stack_ref(program, inputs, params)
            return tuple(out[v] for v in program.outputs)
        STATS.record("fwd_generated")
        out = rows.fused_rows_call(program, inputs, params,
                                   tile_rows=tile_rows,
                                   interpret=interpret)
        return tuple(out[v] for v in program.outputs)

    @jax.custom_vjp
    def run(in_list, p_list):
        return _forward(in_list, p_list)

    def _fwd(in_list, p_list):
        return _forward(in_list, p_list), (in_list, p_list)

    def _bwd(res, g):
        in_list, p_list = res
        # Depth-first backward: recompute the chain on the VMEM tile and
        # apply the VJP rules in reverse — one HBM read per input, one
        # write per cotangent, grid-summed parameter grads.
        if generated_bwd and is_nhwc and _nhwc_generated(in_list):
            STATS.record("bwd_generated")
            dx, dextras, dparams = nhwc_bwd.fused_nhwc_bwd_call(
                program, in_list[0], dict(zip(names[1:], in_list[1:])),
                dict(zip(pnames, p_list)), g[0],
                tile_out_h=tile_out_h, tile_out_w=tile_out_w,
                interpret=interpret)
            return ((dx,) + tuple(dextras[n] for n in names[1:]),
                    tuple(dparams[p] for p in pnames))
        if generated_bwd and not is_nhwc:
            STATS.record("bwd_generated")
            dins, dparams = rows_bwd.fused_rows_bwd_call(
                program, dict(zip(names, in_list)),
                dict(zip(pnames, p_list)),
                dict(zip(program.outputs, g)),
                tile_rows=tile_rows, interpret=interpret)
            return (tuple(dins[n] for n in names),
                    tuple(dparams[p] for p in pnames))

        STATS.record("bwd_reference")

        def reference(ins, ps):
            out = ref.fused_stack_ref(program, dict(zip(names, ins)),
                                      dict(zip(pnames, ps)))
            return tuple(out[v] for v in program.outputs)

        _, vjp = jax.vjp(reference, in_list, p_list)
        din, dp = vjp(tuple(g))
        return din, dp

    run.defvjp(_fwd, _bwd)
    return FusedExecutable(program=program, tile_rows=tile_rows,
                           tile_out_h=tile_out_h, tile_out_w=tile_out_w,
                           interpret=interpret, call=run,
                           generated_bwd=generated_bwd)


def fused_stack_apply(program: ir.StackProgram,
                      inputs: Mapping[str, jnp.ndarray],
                      params: Mapping[str, jnp.ndarray],
                      *,
                      mode: str = "xla",
                      tile_rows: int = 256,
                      tile_out_h: int = 8,
                      tile_out_w: int = 8,
                      interpret: bool = True) -> dict[str, jnp.ndarray]:
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if mode == "barrier":
        return ref.fused_stack_ref(program, inputs, params, barrier=True)
    if mode == "xla":
        return ref.fused_stack_ref(program, inputs, params)

    # mode == 'brainslug': differentiable Pallas dispatch.
    exe = get_executable(program, tile_rows=tile_rows, tile_out_h=tile_out_h,
                         tile_out_w=tile_out_w, interpret=interpret)
    in_list = tuple(inputs[n] for n in program.inputs)
    p_list = tuple(params[p] for p in program.param_names)
    outs = exe.call(in_list, p_list)
    return dict(zip(program.outputs, outs))
