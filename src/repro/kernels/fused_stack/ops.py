"""Jitted wrappers for the generated fused-stack kernels.

``fused_stack_apply`` dispatches one collapsed Sequence:

* mode ``brainslug``  — the generated Pallas kernels (depth-first schedule).
  Training runs depth-first end to end: the forward kernel keeps the tile
  VMEM-resident through the op chain, and the generated backward kernel
  (:mod:`repro.kernels.fused_stack.rows_bwd`) recomputes the chain on the
  resident tile and applies the per-op VJP rules of
  :mod:`repro.core.autodiff` in reverse — no reference-interpreter dispatch
  on the rows hot path.  nhwc / multi-input stacks keep the reference
  backward (fusion changes the schedule, not the math, so the reference VJP
  is exact).
* mode ``xla``        — jit of the interpreter (XLA fuses what it can).
* mode ``barrier``    — per-op ``optimization_barrier`` (paper's
  breadth-first baseline; every intermediate is materialized).

Executables are built once per structural signature + tile geometry and
cached (paper: "If there are multiple equivalent stacks, BRAINSLUG only
generates the code once") — one cache entry holds *both* the forward and the
backward kernel closure.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

import jax
import jax.numpy as jnp

from repro.core import autodiff, ir
from repro.kernels.fused_stack import nhwc, ref, rows, rows_bwd

MODES = ("brainslug", "xla", "barrier")


class DispatchStats:
    """Trace-time dispatch counters (the mode stat the acceptance criteria
    ask for): which backward ran — the generated depth-first kernel or the
    reference-interpreter fallback.  Counts are incremented when the path is
    *traced*, i.e. once per compilation, which is exactly the "was the
    generated kernel used" question."""

    def __init__(self) -> None:
        self.reset()

    def reset(self) -> None:
        self.counts: dict[str, int] = {
            "fwd_generated": 0, "fwd_reference": 0,
            "bwd_generated": 0, "bwd_reference": 0,
        }

    def record(self, key: str) -> None:
        self.counts[key] += 1


STATS = DispatchStats()


@dataclasses.dataclass(frozen=True)
class FusedExecutable:
    """One generated forward+backward pair for a Sequence (brainslug mode)."""

    program: ir.StackProgram
    tile_rows: int
    tile_out_h: int
    tile_out_w: int
    interpret: bool
    call: Callable[..., tuple[jnp.ndarray, ...]]   # (in_list, p_list) -> outs
    generated_bwd: bool                            # rows depth-first backward?


_EXEC_CACHE: dict[tuple, FusedExecutable] = {}


def get_executable(program: ir.StackProgram, *, tile_rows: int = 256,
                   tile_out_h: int = 8, tile_out_w: int = 8,
                   interpret: bool = True) -> FusedExecutable:
    """Build (or fetch) the cached forward+backward executable for
    ``program`` at the given tile geometry, keyed on the structural
    signature so equivalent stacks share one generated pair."""
    key = (program.signature(), tile_rows, tile_out_h, tile_out_w, interpret)
    exe = _EXEC_CACHE.get(key)
    if exe is None:
        exe = _build_executable(program, tile_rows, tile_out_h, tile_out_w,
                                interpret)
        _EXEC_CACHE[key] = exe
    return exe


def clear_executable_cache() -> None:
    _EXEC_CACHE.clear()


def _build_executable(program: ir.StackProgram, tile_rows: int,
                      tile_out_h: int, tile_out_w: int,
                      interpret: bool) -> FusedExecutable:
    names = tuple(program.inputs)
    pnames = tuple(program.param_names)
    rows_path = program.layout == "rows" or len(names) > 1
    generated_bwd = (program.layout == "rows" and autodiff.supports(program))

    def _forward(in_list, p_list):
        inputs = dict(zip(names, in_list))
        params = dict(zip(pnames, p_list))
        if rows_path:
            if program.layout == "nhwc":
                # multi-input nhwc stacks fall back to the XLA path
                STATS.record("fwd_reference")
                out = ref.fused_stack_ref(program, inputs, params)
                return tuple(out[v] for v in program.outputs)
            STATS.record("fwd_generated")
            out = rows.fused_rows_call(program, inputs, params,
                                       tile_rows=tile_rows,
                                       interpret=interpret)
            return tuple(out[v] for v in program.outputs)
        STATS.record("fwd_generated")
        y = nhwc.fused_nhwc_call(program, inputs[names[0]], params,
                                 tile_out_h=tile_out_h,
                                 tile_out_w=tile_out_w,
                                 interpret=interpret)
        return (y,)

    @jax.custom_vjp
    def run(in_list, p_list):
        return _forward(in_list, p_list)

    def _fwd(in_list, p_list):
        return _forward(in_list, p_list), (in_list, p_list)

    def _bwd(res, g):
        in_list, p_list = res
        if generated_bwd:
            # Depth-first backward: recompute the chain on the VMEM tile and
            # apply the VJP rules in reverse — one HBM read per input, one
            # write per cotangent, grid-summed parameter grads.
            STATS.record("bwd_generated")
            dins, dparams = rows_bwd.fused_rows_bwd_call(
                program, dict(zip(names, in_list)),
                dict(zip(pnames, p_list)),
                dict(zip(program.outputs, g)),
                tile_rows=tile_rows, interpret=interpret)
            return (tuple(dins[n] for n in names),
                    tuple(dparams[p] for p in pnames))

        STATS.record("bwd_reference")

        def reference(ins, ps):
            out = ref.fused_stack_ref(program, dict(zip(names, ins)),
                                      dict(zip(pnames, ps)))
            return tuple(out[v] for v in program.outputs)

        _, vjp = jax.vjp(reference, in_list, p_list)
        din, dp = vjp(tuple(g))
        return din, dp

    run.defvjp(_fwd, _bwd)
    return FusedExecutable(program=program, tile_rows=tile_rows,
                           tile_out_h=tile_out_h, tile_out_w=tile_out_w,
                           interpret=interpret, call=run,
                           generated_bwd=generated_bwd)


def fused_stack_apply(program: ir.StackProgram,
                      inputs: Mapping[str, jnp.ndarray],
                      params: Mapping[str, jnp.ndarray],
                      *,
                      mode: str = "xla",
                      tile_rows: int = 256,
                      tile_out_h: int = 8,
                      tile_out_w: int = 8,
                      interpret: bool = True) -> dict[str, jnp.ndarray]:
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if mode == "barrier":
        return ref.fused_stack_ref(program, inputs, params, barrier=True)
    if mode == "xla":
        return ref.fused_stack_ref(program, inputs, params)

    # mode == 'brainslug': differentiable Pallas dispatch.
    exe = get_executable(program, tile_rows=tile_rows, tile_out_h=tile_out_h,
                         tile_out_w=tile_out_w, interpret=interpret)
    in_list = tuple(inputs[n] for n in program.inputs)
    p_list = tuple(params[p] for p in program.param_names)
    outs = exe.call(in_list, p_list)
    return dict(zip(program.outputs, outs))
