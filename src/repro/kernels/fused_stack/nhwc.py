"""Generated depth-first kernel for nhwc-layout stacks (pooling chains).

This is the faithful TPU port of the paper's collapsed CNN kernel
(paper Listing 2): a grid cell produces one ``(tile_out_h, tile_out_w, C)``
output patch by loading the receptive-field-grown input region (halo) into
VMEM and pushing it through every op of the sequence depth-first.

Halo mechanics
--------------
BlockSpec partitions are non-overlapping, but stacked stride-1 pooling needs
overlapping input regions.  The TPU-idiomatic answer is to keep the input in
``ANY`` (HBM) memory space and issue an explicit windowed copy per grid cell
(on hardware: an async DMA; under ``interpret=True``: a dynamic-slice load).
The wrapper pre-pads the input so window origins are always in-bounds, and
per-pool *validity masks* — computed from global coordinates with
``broadcasted_iota`` — replace out-of-image positions with the pool's
neutral element (−inf for max, 0 for avg), reproducing each pooling layer's
own padding semantics exactly.  See ``ref.py`` for the oracle.

Pooling inside the kernel is expressed as a static unrolled max/add over
``window`` shifted strided slices of the VMEM tile — ``reduce_window`` does
not exist inside Mosaic, shifted slices map onto plain VPU ops.

Beyond the single-input chain, the kernel carries *broadcast side operands*
(extra stack inputs whose every non-channel dim is 1, e.g. a saved
channelwise bias consumed by a residual ``EW_BINARY``): they ride along like
parameters in ``(1, C)`` blocks, which lifts the multi-input-nhwc fallback
for that family.  Spatially-extended extra inputs still fall back.

The tile recompute (:func:`run_tile`) is shared with the generated backward
(:mod:`repro.kernels.fused_stack.nhwc_bwd`) — one halo/mask semantics, two
kernels.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import autodiff
from repro.core import ir


@dataclasses.dataclass(frozen=True)
class _Level:
    """Static spatial geometry of one value level inside the sequence."""
    extent_h: int            # tile extent at this level
    extent_w: int
    image_h: int             # full (unpadded) image extent at this level
    image_w: int
    # origin of the tile at this level = out_patch_origin * prod(strides) - off
    mul_h: int
    off_h: int
    mul_w: int
    off_w: int


def _plan_levels(ops: tuple[ir.OpNode, ...], out_h: int, out_w: int,
                 image_hw: list[tuple[int, int]]) -> list[_Level]:
    """Walk backwards from the output patch to compute, per op, the tile
    extent and origin transform of its *input* level.  Per-level *image*
    extents come from forward shape inference (``image_hw``, one entry per
    value level): reconstructing them backwards via pool_in_extent
    under-counts whenever a stride does not tile the image exactly, which
    mis-masks real border columns."""
    levels: list[_Level] = []
    eh, ew = out_h, out_w
    mul_h = mul_w = 1
    off_h = off_w = 0
    # level after the last op (the output level)
    ih, iw = image_hw[len(ops)]
    levels.append(_Level(eh, ew, ih, iw, mul_h, off_h, mul_w, off_w))
    for i, op in enumerate(reversed(ops)):
        if op.kind == ir.OpKind.POOL2D:
            kh, kw = op.attrs["window"]
            sh, sw = op.attrs["stride"]
            ph, pw = op.attrs["padding"]
            eh = ir.pool_in_extent(eh, kh, sh)
            ew = ir.pool_in_extent(ew, kw, sw)
            off_h = off_h * sh + ph
            off_w = off_w * sw + pw
            mul_h *= sh
            mul_w *= sw
        ih, iw = image_hw[len(ops) - 1 - i]
        levels.append(_Level(eh, ew, ih, iw, mul_h, off_h, mul_w, off_w))
    levels.reverse()           # levels[i] = input level of ops[i]
    return levels


def out_block_index(i, j, k):
    """Output BlockSpec index map: grid cell ``(n, i, j)`` owns output
    patch ``(n, i, j)``.  Module-level (not a lambda) so the static
    verifier's write model (:func:`write_model`) evaluates the same
    function the ``pallas_call`` BlockSpec installs."""
    return (i, j, k, 0)


def shared_block_index(i, j, k):
    """BlockSpec index map for ``(1, C)`` param / broadcast-extra blocks:
    every grid cell addresses the single shared block."""
    del i, j, k
    return (0, 0)


def write_model(n: int, oh: int, ow: int, c: int,
                th: int, tw: int) -> list[dict]:
    """The forward kernel's output-write geometry, as data, for the static
    verifier: one ``(1, th, tw, C)`` patch per grid cell into the
    grid-padded output array (pairwise disjoint by construction — proved,
    not assumed, by ``repro.core.verify``)."""
    pad_oh = (-oh) % th
    pad_ow = (-ow) % tw
    return [{
        "name": "out", "block_shape": (1, th, tw, c),
        "index_map": out_block_index,
        "array_shape": (n, oh + pad_oh, ow + pad_ow, c),
        "accumulate": None}]


def _pool_tile(x: jnp.ndarray, op: ir.OpNode, out_h: int, out_w: int
               ) -> jnp.ndarray:
    kh, kw = op.attrs["window"]
    sh, sw = op.attrs["stride"]
    acc = None
    for di in range(kh):
        for dj in range(kw):
            part = x[di: di + (out_h - 1) * sh + 1: sh,
                     dj: dj + (out_w - 1) * sw + 1: sw, :]
            if acc is None:
                acc = part
            elif op.fn == "max":
                acc = jnp.maximum(acc, part)
            else:
                acc = acc + part
    if op.fn == "avg":
        acc = acc / float(kh * kw)
    return acc


def tile_valid(shape_hw: tuple[int, int], origin: tuple, level: _Level
               ) -> jnp.ndarray:
    """``(h, w, 1)`` bool mask: which tile positions lie inside the true
    (unpadded) image at ``level``, given the tile's global ``origin``."""
    rh = origin[0] + jax.lax.broadcasted_iota(jnp.int32, shape_hw, 0)
    rw = origin[1] + jax.lax.broadcasted_iota(jnp.int32, shape_hw, 1)
    return ((rh >= 0) & (rh < level.image_h)
            & (rw >= 0) & (rw < level.image_w))[..., None]


def run_tile(program: ir.StackProgram, levels: list[_Level],
             buf: jnp.ndarray, extras: Mapping[str, jnp.ndarray],
             params: Mapping[str, jnp.ndarray], g0h, g0w
             ) -> tuple[dict, dict, dict, dict]:
    """Depth-first forward of the whole op chain on one resident tile.

    ``buf`` is the halo-grown input patch with global origin ``(g0h, g0w)``
    (unpadded image coordinates); ``extras`` are broadcast side operands as
    ``(1, 1, C)`` values.  Returns ``(env, origins, masked, valids)`` where
    ``masked[op.name]``/``valids[op.name]`` are each pool's neutral-masked
    input and validity mask — exactly what the backward's reverse sweep
    needs.  Shared by the forward and backward kernels so the recompute
    cannot drift from the forward.
    """
    env: dict[str, jnp.ndarray] = {program.inputs[0]: buf}
    env.update(extras)
    origins: dict[str, tuple] = {name: (0, 0) for name in extras}
    origins[program.inputs[0]] = (g0h, g0w)
    masked: dict[str, jnp.ndarray] = {}
    valids: dict[str, jnp.ndarray] = {}

    for i, op in enumerate(program.ops):
        lv_in = levels[i]
        lv_out = levels[i + 1]
        if op.kind == ir.OpKind.POOL2D:
            x = env[op.inputs[0]]
            oh, ow = origins[op.inputs[0]]
            # mask positions outside the true image at this level; fill with
            # the pool's neutral element = that pool's padding semantics.
            valid = tile_valid(x.shape[:2], (oh, ow), lv_in)
            x = jnp.where(valid, x, autodiff.pool_neutral(x.dtype, op.fn))
            masked[op.name] = x
            valids[op.name] = valid
            y = _pool_tile(x, op, lv_out.extent_h, lv_out.extent_w)
            sh, sw = op.attrs["stride"]
            ph, pw = op.attrs["padding"]
            # exact by construction: origin_in = origin_out * s - p
            origins[op.output] = ((oh + ph) // sh, (ow + pw) // sw)
            env[op.output] = y
        else:
            env[op.output] = ir.apply_op(op, env, params)
            # anchor the origin on a spatial operand (broadcast extras carry
            # no coordinates of their own)
            anchor = next((v for v in op.inputs if v not in extras),
                          op.inputs[0])
            origins[op.output] = origins[anchor]
    return env, origins, masked, valids


def _kernel(program: ir.StackProgram, levels: list[_Level],
            pad_off_h: int, pad_off_w: int, n_extra: int, n_params: int,
            *refs) -> None:
    src_ref = refs[0]
    extra_refs = refs[1: 1 + n_extra]
    param_refs = refs[1 + n_extra: 1 + n_extra + n_params]
    out_ref = refs[1 + n_extra + n_params]

    n = pl.program_id(0)
    pi = pl.program_id(1)
    pj = pl.program_id(2)

    lv0 = levels[0]
    out_lv = levels[-1]
    # tile origin at the input level, in *unpadded* image coordinates
    g0h = pi * out_lv.extent_h * lv0.mul_h - lv0.off_h
    g0w = pj * out_lv.extent_w * lv0.mul_w - lv0.off_w
    # load from the pre-padded array (always in-bounds)
    buf = src_ref[n, pl.dslice(g0h + pad_off_h, lv0.extent_h),
                  pl.dslice(g0w + pad_off_w, lv0.extent_w), :]

    # (1, C) param / broadcast-extra blocks against (h, w, C) tiles.
    extras = {name: ref[...][None] for name, ref in
              zip(program.inputs[1:], extra_refs)}
    params = {name: ref[...] for name, ref in
              zip(program.param_names, param_refs)}

    env, _, _, _ = run_tile(program, levels, buf, extras, params, g0h, g0w)
    out_ref[...] = env[program.outputs[0]][None]


def plan_geometry(program: ir.StackProgram, x: jnp.ndarray,
                  extras: Mapping[str, jnp.ndarray],
                  tile_out_h: int, tile_out_w: int):
    """Shared forward/backward geometry: levels, grid, clamped tile extents,
    and the pre-padded input (every halo load in-bounds).  Returns
    ``(levels, grid, xp, (left_h, left_w), (oh, ow), (pad_oh, pad_ow),
    (th, tw))``."""
    n, h, w, c = x.shape
    in_shapes = {program.inputs[0]: x.shape}
    in_shapes.update({k: jnp.shape(v) for k, v in extras.items()})
    shapes = ir.infer_shapes(program, in_shapes)
    _, oh, ow, _ = shapes[program.outputs[0]]

    th = min(tile_out_h, oh)
    tw = min(tile_out_w, ow)
    pad_oh = (-oh) % th
    pad_ow = (-ow) % tw
    grid = (n, (oh + pad_oh) // th, (ow + pad_ow) // tw)

    image_hw = [(h, w)]
    for op in program.ops:
        s_ = shapes[op.output]
        image_hw.append((s_[1], s_[2]))
    levels = _plan_levels(program.ops, th, tw, image_hw)
    lv0 = levels[0]

    # Pre-pad the input so every halo load is in-bounds.  Left pad covers the
    # most negative origin (off); right pad covers the last tile's reach.
    left_h, left_w = lv0.off_h, lv0.off_w
    last_g0h = (grid[1] - 1) * th * lv0.mul_h - lv0.off_h
    last_g0w = (grid[2] - 1) * tw * lv0.mul_w - lv0.off_w
    right_h = max(0, last_g0h + lv0.extent_h - h)
    right_w = max(0, last_g0w + lv0.extent_w - w)
    xp = jnp.pad(x, ((0, 0), (left_h, right_h), (left_w, right_w), (0, 0)))
    return (levels, grid, xp, (left_h, left_w), (oh, ow), (pad_oh, pad_ow),
            (th, tw))


def prep_extras(program: ir.StackProgram,
                extras: Mapping[str, jnp.ndarray]) -> list[jnp.ndarray]:
    """Broadcast side operands as (1, C) blocks (the param convention)."""
    vals = []
    for name in program.inputs[1:]:
        v = jnp.asarray(extras[name])
        vals.append(v.reshape(1, -1))
    return vals


def fused_nhwc_call(program: ir.StackProgram,
                    x: jnp.ndarray,
                    params: Mapping[str, jnp.ndarray],
                    *,
                    extras: Mapping[str, jnp.ndarray] | None = None,
                    tile_out_h: int = 8,
                    tile_out_w: int = 8,
                    interpret: bool = True) -> jnp.ndarray:
    """Run an nhwc sequence as one fused Pallas kernel.

    ``x`` is the spatial input (``program.inputs[0]``); ``extras`` maps any
    remaining program inputs to broadcast side operands (every non-channel
    dim 1).  Spatially-extended extra inputs are not supported here — the
    dispatcher falls back to the reference path for those.
    """
    extras = dict(extras or {})
    missing = [v for v in program.inputs[1:] if v not in extras]
    if missing:
        raise ValueError(f"{program.name}: missing extra inputs {missing}; "
                         "spatially-extended multi-input stacks fall back "
                         "to the XLA path")
    n, h, w, c = x.shape
    (levels, grid, xp, (left_h, left_w), (oh, ow), (pad_oh, pad_ow),
     (th, tw)) = plan_geometry(program, x, extras, tile_out_h, tile_out_w)

    evals = prep_extras(program, extras)
    pnames = list(program.param_names)
    pvals = [jnp.asarray(params[p]).reshape(1, -1) for p in pnames]

    in_specs = [pl.BlockSpec(memory_space=pl.ANY)]
    in_specs += [pl.BlockSpec((1, v.shape[-1]), shared_block_index)
                 for v in evals + pvals]
    out_spec = pl.BlockSpec((1, th, tw, c), out_block_index)
    out_shape = jax.ShapeDtypeStruct((n, oh + pad_oh, ow + pad_ow, c), x.dtype)

    fn = pl.pallas_call(
        functools.partial(_kernel, program, levels, left_h, left_w,
                          len(evals), len(pvals)),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )
    out = fn(xp, *evals, *pvals)
    return out[:, :oh, :ow, :]
