"""Generated depth-first **backward** kernel for rows-layout stacks.

The forward kernel (:mod:`repro.kernels.fused_stack.rows`) keeps a
``(tile_rows, F)`` tile VMEM-resident through a whole collapsed Sequence.
This module generates the training twin: one ``pl.pallas_call`` that

1. *recomputes* the sequence's forward ops on the resident tile (the
   depth-first analogue of activation rematerialization — intermediates are
   never written to HBM, neither in the forward nor here),
2. runs the per-op VJP rules of :mod:`repro.core.autodiff` in reverse while
   everything is still VMEM-resident,
3. writes each input cotangent tile once, and
4. accumulates per-feature parameter gradients across the grid into ``(1, F)``
   accumulator blocks (all grid cells map to the same output block; TPU grid
   iterations are sequential, so ``ref[...] +=`` is a race-free reduction —
   the grid-sum epilogue pattern).

Padded rows carry zero cotangents (the wrapper zero-pads ``g``) and are
additionally excluded from the parameter-gradient reduction by a row-validity
mask, so a NaN/inf primal recomputed on an all-zero padded row cannot poison
the accumulators.
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import autodiff, ir
from repro.kernels.fused_stack import rows


def write_model(program: ir.StackProgram,
                shapes: Mapping[str, tuple[int, ...]],
                tile_rows: int, padded_rows: int) -> list[dict]:
    """The backward kernel's output-write geometry, as data, for the
    static verifier: one disjoint ``(tile_rows, F)`` cotangent block per
    input, plus one shared ``(1, F)`` accumulator per parameter — the
    sanctioned sequential-grid reduction idiom (``accumulate='grid-sum'``:
    every grid cell must address the *same* block)."""
    models = []
    for name in program.inputs:
        f = shapes[name][-1]
        models.append({
            "name": f"din:{name}", "block_shape": (tile_rows, f),
            "index_map": rows.row_block_index,
            "array_shape": (padded_rows, f), "accumulate": None})
    for pname in program.param_names:
        f = next((shapes[op.output][-1] for op in program.ops
                  if pname in op.params and op.output in shapes), 1)
        models.append({
            "name": f"dparam:{pname}", "block_shape": (1, f),
            "index_map": rows.shared_block_index,
            "array_shape": (1, f), "accumulate": "grid-sum"})
    return models


def _bwd_kernel(program: ir.StackProgram, n_inputs: int, n_params: int,
                n_outputs: int, tile_rows: int, valid_rows: int | None,
                *refs) -> None:
    in_refs = refs[:n_inputs]
    param_refs = refs[n_inputs:n_inputs + n_params]
    g_refs = refs[n_inputs + n_params:n_inputs + n_params + n_outputs]
    din_refs = refs[n_inputs + n_params + n_outputs:
                    n_inputs + n_params + n_outputs + n_inputs]
    dparam_refs = refs[n_inputs + n_params + n_outputs + n_inputs:]

    env = {name: ref[...] for name, ref in zip(program.inputs, in_refs)}
    params = {name: ref[...] for name, ref in
              zip(program.param_names, param_refs)}

    # (1) depth-first recompute: the whole op chain on the resident tile.
    for op in program.ops:
        env[op.output] = ir.apply_op(op, env, params)

    # (2) reverse sweep with the shared VJP rule table.  When the row count
    # is not a tile multiple the tail tile carries zero-padded rows; their
    # cotangents are zero, but the recomputed primal can still be NaN/inf
    # there (e.g. div on all-zero rows), so the rules get a validity mask to
    # exclude those rows from the parameter-gradient reduction.
    row_mask = None
    if valid_rows is not None:
        row0 = pl.program_id(0) * tile_rows
        ids = row0 + jax.lax.broadcasted_iota(jnp.int32, (tile_rows, 1), 0)
        row_mask = ids < valid_rows
    gouts = {name: ref[...] for name, ref in zip(program.outputs, g_refs)}
    dins, dparams = autodiff.program_vjp(program, env, params, gouts,
                                         row_mask)

    # (3) input cotangents: one write per tile.
    for name, ref in zip(program.inputs, din_refs):
        ref[...] = dins[name]

    # (4) parameter gradients: zero-init on the first grid cell, then
    # accumulate every tile's (1, F) partial into the shared block.
    if dparam_refs:
        @pl.when(pl.program_id(0) == 0)
        def _init():
            for ref in dparam_refs:
                ref[...] = jnp.zeros(ref.shape, ref.dtype)

        for pname, ref in zip(program.param_names, dparam_refs):
            ref[...] += dparams[pname]


def fused_rows_bwd_call(program: ir.StackProgram,
                        inputs: Mapping[str, jnp.ndarray],
                        params: Mapping[str, jnp.ndarray],
                        cotangents: Mapping[str, jnp.ndarray],
                        *,
                        tile_rows: int = 256,
                        interpret: bool = True
                        ) -> tuple[dict[str, jnp.ndarray],
                                   dict[str, jnp.ndarray]]:
    """Run the generated recompute-in-tile backward for one sequence.

    ``cotangents`` maps each program output name to its incoming cotangent
    (same leading shape as the inputs).  Returns ``(dinputs, dparams)`` keyed
    by input / parameter name, with shapes and dtypes matching the primals.
    """
    names = list(program.inputs)
    pnames = list(program.param_names)
    flat, lead, rows_n, pad = rows.flatten_rows(program.name, names, inputs,
                                                tile_rows)
    grid = ((rows_n + pad) // tile_rows,)

    pvals = rows.prep_params(program, params)

    gflat, glead, _, _ = rows.flatten_rows(
        program.name, list(program.outputs), cotangents, tile_rows)
    if glead != lead:
        raise ValueError(f"{program.name}: cotangent leading shape {glead} "
                         f"!= input leading shape {lead}")

    din_shapes = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in flat]
    dparam_shapes = [jax.ShapeDtypeStruct(v.shape, v.dtype) for v in pvals]

    in_specs = [pl.BlockSpec((tile_rows, a.shape[-1]), rows.row_block_index)
                for a in flat]
    in_specs += [pl.BlockSpec((1, v.shape[-1]), rows.shared_block_index)
                 for v in pvals]
    in_specs += [pl.BlockSpec((tile_rows, g.shape[-1]),
                              rows.row_block_index) for g in gflat]
    out_specs = [pl.BlockSpec((tile_rows, a.shape[-1]),
                              rows.row_block_index) for a in flat]
    # Parameter-grad accumulators: every grid cell addresses block (0, 0).
    out_specs += [pl.BlockSpec((1, v.shape[-1]), rows.shared_block_index)
                  for v in pvals]

    fn = pl.pallas_call(
        functools.partial(_bwd_kernel, program, len(flat), len(pvals),
                          len(gflat), tile_rows, rows_n if pad else None),
        grid=grid,
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(din_shapes + dparam_shapes),
        interpret=interpret,
    )
    outs = fn(*flat, *pvals, *gflat)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)

    dins: dict[str, jnp.ndarray] = {}
    for name, d in zip(names, outs[: len(names)]):
        d = d[:rows_n] if pad else d
        dins[name] = d.reshape(*lead, d.shape[-1])
    dparams: dict[str, jnp.ndarray] = {}
    for pname, d in zip(pnames, outs[len(names):]):
        dparams[pname] = d.reshape(jnp.shape(params[pname]))
    return dins, dparams
