"""Fused SwiGLU / GeGLU gate kernel: ``y = act(gate) * up``.

Depth-first over ``(block_rows, F)`` tiles: gate and up are each read once,
the activation and product happen in VMEM, one write.  Breadth-first
materializes ``act(gate)`` to HBM first (an extra full read+write of an
``(T, d_ff)`` tensor — the largest activation in the block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "squared_relu": lambda x: jnp.square(jnp.maximum(x, 0.0)),
}


def _kernel(act: str, g_ref, u_ref, y_ref) -> None:
    g = g_ref[...]
    y_ref[...] = (_ACTS[act](g.astype(jnp.float32)).astype(g.dtype)
                  * u_ref[...])


def swiglu_fwd(gate: jnp.ndarray, up: jnp.ndarray, *, act: str = "silu",
               block_rows: int = 256, interpret: bool = True) -> jnp.ndarray:
    if act not in _ACTS:
        raise ValueError(f"unknown activation {act!r}")
    lead = gate.shape[:-1]
    f = gate.shape[-1]
    rows = 1
    for s in lead:
        rows *= s
    gf = gate.reshape(rows, f)
    uf = up.reshape(rows, f)
    block_rows = min(block_rows, max(rows, 1))
    pad = (-rows) % block_rows
    if pad:
        gf = jnp.pad(gf, ((0, pad), (0, 0)))
        uf = jnp.pad(uf, ((0, pad), (0, 0)))
    tile = pl.BlockSpec((block_rows, f), lambda i: (i, 0))
    y = pl.pallas_call(
        functools.partial(_kernel, act),
        grid=((rows + pad) // block_rows,),
        in_specs=[tile, tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows + pad, f), gate.dtype),
        interpret=interpret,
    )(gf, uf)
    return y[:rows].reshape(*lead, f)
