"""Differentiable dispatch for the fused SwiGLU gate."""
from __future__ import annotations

import functools

import jax

from repro.kernels.swiglu import ref as ref_mod
from repro.kernels.swiglu import swiglu as kernel_mod

#: Gate activations the fused kernel implements — the kernel registry
#: only rewrites ``act(gate) * up`` clusters whose act is one of these.
ACTS = tuple(kernel_mod._ACTS)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def swiglu(gate, up, act: str = "silu", block_rows: int = 256,
           interpret: bool = True):
    return kernel_mod.swiglu_fwd(gate, up, act=act, block_rows=block_rows,
                                 interpret=interpret)


def _fwd(gate, up, act, block_rows, interpret):
    return swiglu(gate, up, act, block_rows, interpret), (gate, up)


def _bwd(act, block_rows, interpret, res, g):
    gate, up = res
    _, vjp = jax.vjp(lambda a, b: ref_mod.swiglu_ref(a, b, act=act), gate, up)
    return vjp(g)


swiglu.defvjp(_fwd, _bwd)
