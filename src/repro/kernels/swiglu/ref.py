"""Pure-jnp oracle for the SwiGLU gate."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "squared_relu": lambda x: jnp.square(jnp.maximum(x, 0.0)),
}


def swiglu_ref(gate: jnp.ndarray, up: jnp.ndarray,
               *, act: str = "silu") -> jnp.ndarray:
    return (_ACTS[act](gate.astype(jnp.float32)).astype(gate.dtype) * up)
