"""Differentiable dispatch for the fused vocab cross-entropy.

Forward runs the depth-first kernel; backward recomputes through a
V-chunked reference (same pattern as the other kernels: fused forward,
recompute backward — the (T, V) logits are never stored)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.vocab_ce import ce as kernel_mod


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def fused_nll(h, w, labels, block_rows: int = 128, block_v: int = 512,
              block_d: int = 512, interpret: bool = True):
    """Mean masked NLL over (T, D) hidden states against a (D, V) head."""
    lse, gold = kernel_mod.fused_ce_fwd(
        h, w, labels, block_rows=block_rows, block_v=block_v,
        block_d=block_d, interpret=interpret)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _fwd(h, w, labels, block_rows, block_v, block_d, interpret):
    lse, gold = kernel_mod.fused_ce_fwd(
        h, w, labels, block_rows=block_rows, block_v=block_v,
        block_d=block_d, interpret=interpret)
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    nll = jnp.sum((lse - gold) * mask) / denom
    return nll, (h, w, labels, lse, mask, denom)


def _bwd(block_rows, block_v, block_d, interpret, res, g):
    """d nll / dh = (softmax - onehot) W^T * mask / denom, computed in
    V-chunks against the saved logsumexp — O(T*D + chunk) memory."""
    h, w, labels, lse, mask, denom = res
    t, d = h.shape
    v = w.shape[1]
    scale = (g * mask / denom).astype(jnp.float32)          # (T,)
    safe = jnp.maximum(labels, 0)

    nv = -(-v // block_v)
    wpad = (-v) % block_v
    w_p = jnp.pad(w, ((0, 0), (0, wpad))) if wpad else w

    def chunk(carry, j):
        dh, dw = carry
        lo = j * block_v
        wc = jax.lax.dynamic_slice_in_dim(w_p, lo, block_v, axis=1)
        logits = h.astype(jnp.float32) @ wc.astype(jnp.float32)
        col = lo + jnp.arange(block_v)[None, :]
        p = jnp.exp(logits - lse[:, None])
        p = jnp.where(col < v, p, 0.0)
        onehot = (col == safe[:, None]) & (labels >= 0)[:, None]
        dlogits = (p - onehot.astype(jnp.float32)) * scale[:, None]
        dh = dh + dlogits @ wc.astype(jnp.float32).T
        dw = jax.lax.dynamic_update_slice_in_dim(
            dw, (h.astype(jnp.float32).T @ dlogits).astype(dw.dtype),
            lo, axis=1)
        return (dh, dw), None

    dh0 = jnp.zeros((t, d), jnp.float32)
    dw0 = jnp.zeros_like(w_p, jnp.float32)
    (dh, dw), _ = jax.lax.scan(
        functools.partial(chunk), (dh0, dw0), jnp.arange(nv))
    if wpad:
        dw = dw[:, :v]
    return dh.astype(h.dtype), dw.astype(w.dtype), None


fused_nll.defvjp(_fwd, _bwd)


# ---------------------------------------------------------------------------
# Per-token gold log-probability — the kernel-registry entry point.
#
# The registry matches the *value* form of the loss tail
# (``take_along_axis(log_softmax(h @ w), labels)``), whose output is one
# gold log-prob per row, not the reduced mean — the user's own mask /
# mean ops stay in the graph downstream.  Forward runs the same fused
# (lse, gold) kernel; backward recomputes in V-chunks with a *per-token*
# cotangent instead of fused_nll's mask/denom scale.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def fused_gold_logp(h, w, labels, block_rows: int = 128, block_v: int = 512,
                    block_d: int = 512, interpret: bool = True):
    """Per-token ``log_softmax(h @ w)[t, labels[t]]`` (T,) f32; negative
    labels wrap python-style, matching ``jnp.take_along_axis``."""
    v = w.shape[1]
    wrapped = jnp.where(labels < 0, labels + v, labels).astype(jnp.int32)
    lse, gold = kernel_mod.fused_ce_fwd(
        h, w, wrapped, block_rows=block_rows, block_v=block_v,
        block_d=block_d, interpret=interpret)
    return gold - lse


def _glp_fwd(h, w, labels, block_rows, block_v, block_d, interpret):
    v = w.shape[1]
    wrapped = jnp.where(labels < 0, labels + v, labels).astype(jnp.int32)
    lse, gold = kernel_mod.fused_ce_fwd(
        h, w, wrapped, block_rows=block_rows, block_v=block_v,
        block_d=block_d, interpret=interpret)
    return gold - lse, (h, w, wrapped, lse)


def _glp_bwd(block_rows, block_v, block_d, interpret, res, g):
    """d logp / dlogits = onehot - softmax, scaled per token by ``g`` —
    computed in V-chunks against the saved logsumexp, O(T*D + chunk)."""
    h, w, wrapped, lse = res
    t, d = h.shape
    v = w.shape[1]
    scale = g.astype(jnp.float32)                           # (T,)

    nv = -(-v // block_v)
    wpad = (-v) % block_v
    w_p = jnp.pad(w, ((0, 0), (0, wpad))) if wpad else w

    def chunk(carry, j):
        dh, dw = carry
        lo = j * block_v
        wc = jax.lax.dynamic_slice_in_dim(w_p, lo, block_v, axis=1)
        logits = h.astype(jnp.float32) @ wc.astype(jnp.float32)
        col = lo + jnp.arange(block_v)[None, :]
        p = jnp.exp(logits - lse[:, None])
        p = jnp.where(col < v, p, 0.0)
        onehot = (col == wrapped[:, None]).astype(jnp.float32)
        dlogits = (onehot - p) * scale[:, None]
        dh = dh + dlogits @ wc.astype(jnp.float32).T
        dw = jax.lax.dynamic_update_slice_in_dim(
            dw, (h.astype(jnp.float32).T @ dlogits).astype(dw.dtype),
            lo, axis=1)
        return (dh, dw), None

    dh0 = jnp.zeros((t, d), jnp.float32)
    dw0 = jnp.zeros_like(w_p, jnp.float32)
    (dh, dw), _ = jax.lax.scan(chunk, (dh0, dw0), jnp.arange(nv))
    if wpad:
        dw = dw[:, :v]
    return dh.astype(h.dtype), dw.astype(w.dtype), None


fused_gold_logp.defvjp(_glp_fwd, _glp_bwd)
