"""Pure-jnp oracle for the fused vocab cross-entropy."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ce_ref(h: jnp.ndarray, w: jnp.ndarray, labels: jnp.ndarray
           ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logsumexp (T,), gold_logit (T,)) in f32; labels < 0 give
    gold = 0 (the caller masks those rows)."""
    logits = (h.astype(jnp.float32) @ w.astype(jnp.float32))
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    safe = jnp.maximum(labels, 0)
    gold = jnp.take_along_axis(logits, safe[:, None], axis=-1)[:, 0]
    gold = jnp.where(labels >= 0, gold, 0.0)
    return lse, gold


def nll_ref(h: jnp.ndarray, w: jnp.ndarray, labels: jnp.ndarray
            ) -> jnp.ndarray:
    """Mean masked NLL (labels < 0 masked) — the training-loss form."""
    lse, gold = ce_ref(h, w, labels)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def gold_logp_ref(h: jnp.ndarray, w: jnp.ndarray, labels: jnp.ndarray
                  ) -> jnp.ndarray:
    """Per-token gold log-probability (T,) in f32 — the registry twin of
    ``take_along_axis(log_softmax(h @ w), labels)``.  Negative labels wrap
    python-style (``labels + V``), matching ``jnp.take_along_axis``."""
    v = w.shape[1]
    wrapped = jnp.where(labels < 0, labels + v, labels)
    logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, wrapped[:, None], axis=-1)[:, 0]
    return gold - lse
