"""Depth-first fused cross-entropy over the vocab head.

The (T, V) logits tensor of a big-vocab LM head (paligemma V=257k,
minitron 256k) is the single largest activation of the training step.
Breadth-first execution materializes it to HBM three times (matmul out,
logsumexp in, gather in).  This kernel runs the whole chain

    logits_chunk = h_tile @ W[:, chunk]          (MXU)
    online logsumexp over chunks                 (VPU, f32 stats)
    gold-logit extraction for the label column

depth-first on VMEM tiles: the logits exist only chunk-at-a-time in VMEM
and the outputs are two (T,)-vectors (logsumexp and gold logit).  This is
the same schedule transformation the paper applies to pooling chains,
applied to the head — BrainSlug's "non-matmul chain" restriction lifted
by fusing *through* the matmul with an online reduction (beyond-paper).

Grid: (row_tiles, v_chunks, d_chunks) with d innermost — the partial
matmul accumulates a (bR, bV) logits scratch over d, then the v-level
online-softmax update fires on the last d step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(block_v: int, v_real: int, h_ref, w_ref, lab_ref, lse_ref,
            gold_ref, logits_ref, m_ref, l_ref, g_ref) -> None:
    j = pl.program_id(1)                     # v chunk
    k = pl.program_id(2)                     # d chunk
    nv = pl.num_programs(1)
    nd = pl.num_programs(2)

    @pl.when((j == 0) & (k == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    @pl.when(k == 0)
    def _zero_logits():
        logits_ref[...] = jnp.zeros_like(logits_ref)

    logits_ref[...] += jax.lax.dot_general(
        h_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == nd - 1)
    def _online_update():
        logits = logits_ref[...]                       # (bR, bV) f32
        labels = lab_ref[...]                          # (bR, 1) int32
        col = j * block_v + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1)
        logits = jnp.where(col < v_real, logits, NEG_INF)  # padded vocab
        is_gold = col == labels
        g_ref[...] += jnp.sum(jnp.where(is_gold, logits, 0.0), axis=-1,
                              keepdims=True)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
        l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) + jnp.sum(
            jnp.exp(logits - m_new), axis=-1, keepdims=True)
        m_ref[...] = m_new

        @pl.when(j == nv - 1)
        def _finalize():
            lse_ref[...] = m_ref[...] + jnp.log(
                jnp.maximum(l_ref[...], 1e-30))
            gold_ref[...] = g_ref[...]


def fused_ce_fwd(h: jnp.ndarray, w: jnp.ndarray, labels: jnp.ndarray,
                 *, block_rows: int = 128, block_v: int = 512,
                 block_d: int = 512, interpret: bool = True
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """h: (T, D); w: (D, V); labels: (T,) int32 (may exceed V-1 for pad).
    Returns (logsumexp (T,), gold_logit (T,)) in f32 — the per-row NLL is
    ``lse - gold`` (mask handled by the caller)."""
    t, d = h.shape
    v = w.shape[1]
    block_rows = min(block_rows, t)
    block_v = min(block_v, v)
    block_d = min(block_d, d)
    pr = (-t) % block_rows
    pv = (-v) % block_v
    pd = (-d) % block_d
    hp = jnp.pad(h, ((0, pr), (0, pd))) if (pr or pd) else h
    wp = jnp.pad(w, ((0, pd), (0, pv))) if (pd or pv) else w
    labp = jnp.pad(labels, (0, pr), constant_values=-1) if pr else labels
    labp = labp.reshape(-1, 1).astype(jnp.int32)

    grid = ((t + pr) // block_rows, (v + pv) // block_v,
            (d + pd) // block_d)
    lse, gold = pl.pallas_call(
        functools.partial(_kernel, block_v, v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_d), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_d, block_v), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_rows, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_rows, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i, j, k: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((t + pr, 1), jnp.float32),
            jax.ShapeDtypeStruct((t + pr, 1), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((block_rows, block_v), jnp.float32),
            pltpu.VMEM((block_rows, 1), jnp.float32),
            pltpu.VMEM((block_rows, 1), jnp.float32),
            pltpu.VMEM((block_rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(hp, wp, labp)
    return lse[:t, 0], gold[:t, 0]
