"""Pallas kernel for the SSD intra-chunk block (Mamba2).

Per (batch, head, chunk) grid cell the kernel computes, on VMEM tiles:

    G       = C_c B_c^T                       (L, L) MXU matmul
    M       = G * exp(a_i - a_j) * tril       decay-masked scores
    Y_intra = M @ (dt*x)_c                    (L, P) MXU matmul
    S_c     = (B_c * exp(a_L - a))^T (dt*x)_c (N, P) chunk state

i.e. the whole masked-matmul chain runs depth-first on a chunk tile —
the (L, L) score matrix never exists in HBM.  The tiny inter-chunk state
recurrence stays at the JAX level (``chunked.py``); it is O(S/L) work.

The within-chunk cumulative decay ``a`` is computed at the JAX level too
(an element-wise cumsum that XLA fuses into the surrounding reshapes), so
the kernel body is pure matmul + VPU math — no scans inside Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(chunk: int, dtx_ref, a_ref, b_ref, c_ref, y_ref, s_ref) -> None:
    dtx = dtx_ref[0, 0, 0]                       # (L, P) f32
    a = a_ref[0, 0, 0]                           # (L, 1) f32
    bb = b_ref[0, 0]                             # (L, N) f32
    cc = c_ref[0, 0]                             # (L, N) f32

    g = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (L, L)
    seg = a - a.reshape(1, chunk)                # a_i - a_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    m = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    y_ref[0, 0, 0] = jax.lax.dot_general(
        g * m, dtx, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    a_last = a[chunk - 1]                        # (1,)
    state_decay = jnp.exp(a_last.reshape(1, 1) - a)          # (L, 1)
    s_ref[0, 0, 0] = jax.lax.dot_general(
        bb * state_decay, dtx, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (N, P)


def ssd_intra_chunk(dtx: jnp.ndarray, a: jnp.ndarray, B: jnp.ndarray,
                    C: jnp.ndarray, *, interpret: bool = True):
    """dtx: (b,h,nc,L,P) f32; a: (b,h,nc,L,1) f32; B/C: (b,nc,L,N) f32.
    Returns (y_intra (b,h,nc,L,P), S (b,h,nc,N,P))."""
    b, h, nc, L, p = dtx.shape
    n = B.shape[-1]
    grid = (b, h, nc)
    y, s = pl.pallas_call(
        functools.partial(_kernel, L),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, L, p), lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, 1), lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, L, n), lambda b_, h_, c_: (b_, c_, 0, 0)),
            pl.BlockSpec((1, 1, L, n), lambda b_, h_, c_: (b_, c_, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, 1, L, p), lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, n, p), lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, h, nc, L, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, nc, n, p), jnp.float32),
        ),
        interpret=interpret,
    )(dtx, a, B, C)
    return y, s
