"""Differentiable dispatch for the SSD mixer."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd import chunked as chunked_mod
from repro.kernels.ssd import ssd as kernel_mod


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def ssd(x, dt, A, B, C, D, chunk: int = 64, interpret: bool = True):
    """Pallas-accelerated SSD: intra-chunk work in the kernel, inter-chunk
    state scan at the JAX level.  Matches ``chunked.ssd_chunked`` /
    ``ref.ssd_ref`` bitwise up to f32 reassociation."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = x.shape[1]
    nc = sp // chunk

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h)
    Bf = B.astype(jnp.float32).reshape(b, nc, chunk, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, chunk, n)
    Af = A.astype(jnp.float32)

    dtx = jnp.moveaxis(dtf[..., None] * xf, 3, 1)        # (b,h,nc,L,p)
    dta = dtf * Af[None, None, None, :]
    a = jnp.cumsum(dta, axis=2)                          # (b,nc,L,h)
    a_bh = jnp.moveaxis(a, 3, 1)[..., None]              # (b,h,nc,L,1)

    y_intra, S = kernel_mod.ssd_intra_chunk(dtx, a_bh, Bf, Cf,
                                            interpret=interpret)

    # inter-chunk state recurrence (tiny)
    lam = jnp.exp(jnp.moveaxis(a[:, :, -1], 2, 1))       # (b,h,nc)

    def step(hprev, inputs):
        lam_c, S_c = inputs
        return hprev * lam_c[..., None, None] + S_c, hprev

    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    _, hprevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(lam, 2, 0), jnp.moveaxis(S, 2, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 2)                  # (b,h,nc,n,p)

    y_inter = jnp.einsum("bcln,bhcl,bhcnp->bhclp",
                         Cf, jnp.exp(a_bh[..., 0]), hprevs)
    y = jnp.moveaxis(y_intra + y_inter, 1, 3).reshape(b, sp, h, p)[:, :s]
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] * \
            x.astype(jnp.float32).reshape(b, sp, h, p)[:, :s]
    return y.astype(x.dtype)


def _fwd(x, dt, A, B, C, D, chunk, interpret):
    return ssd(x, dt, A, B, C, D, chunk, interpret), (x, dt, A, B, C, D)


def _bwd(chunk, interpret, res, g):
    x, dt, A, B, C, D = res
    has_d = D is not None

    def f(x_, dt_, A_, B_, C_, D_):
        return chunked_mod.ssd_chunked(x_, dt_, A_, B_, C_,
                                       D_ if has_d else None, chunk=chunk)

    _, vjp = jax.vjp(f, x, dt, A, B, C,
                     D if has_d else jnp.zeros_like(A))
    dx, ddt, dA, dB, dC, dD = vjp(g)
    return dx, ddt, dA, dB, dC, (dD if has_d else None)


ssd.defvjp(_fwd, _bwd)
