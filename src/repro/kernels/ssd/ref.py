"""Pure-jnp oracle for the Mamba2 SSD mixer: exact sequential recurrence.

State update per time step (post-discretization):

    h_t = exp(dt_t * A) * h_{t-1} + B_t (dt_t * x_t)^T      h: (N, P)
    y_t = C_t^T h_t + D * x_t

Shapes: x (B,S,H,P), dt (B,S,H), A (H,), B/C (B,S,N) (single group),
D (H,).  Slow but unambiguous — the oracle every faster path must match.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
            B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray | None = None
            ) -> jnp.ndarray:
    b, s, h, p = x.shape
    n = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(hstate, t):
        dA = jnp.exp(dtf[:, t] * Af[None, :])               # (B, H)
        dBx = jnp.einsum("bn,bhp->bhnp", Bf[:, t],
                         dtf[:, t][..., None] * xf[:, t])   # (B,H,N,P)
        hstate = hstate * dA[..., None, None] + dBx
        y = jnp.einsum("bn,bhnp->bhp", Cf[:, t], hstate)
        return hstate, y

    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    _, ys = jax.lax.scan(step, h0, jnp.arange(s))
    y = jnp.moveaxis(ys, 0, 1)                               # (B,S,H,P)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype)
