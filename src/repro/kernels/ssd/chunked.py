"""Chunked SSD (state-space duality) — pure-JAX production path.

The SSD decomposition (Dao & Gu, 2024) splits the sequence into chunks of
length L: within a chunk the recurrence is a masked matmul (MXU-friendly);
across chunks a tiny (N, P) state is carried by a scan.  This *is* the
depth-first idea at the sequence level — each chunk's O(L²) work happens on
VMEM-resident tiles, and only the (N, P) state crosses chunk boundaries.

All math in float32; cast back at the end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray | None = None,
                *, chunk: int = 64) -> jnp.ndarray:
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h)
    Bf = B.astype(jnp.float32).reshape(b, nc, chunk, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, chunk, n)
    Af = A.astype(jnp.float32)

    dtx = dtf[..., None] * xf                            # (b,nc,L,h,p)
    dta = dtf * Af[None, None, None, :]                  # (b,nc,L,h)
    a = jnp.cumsum(dta, axis=2)                          # inclusive cumsum
    a_last = a[:, :, -1]                                 # (b,nc,h)

    # --- intra-chunk: masked (L, L) attention-like matmul ----------------
    g = jnp.einsum("bcln,bcmn->bclm", Cf, Bf)            # (b,nc,L,L)
    seg = a[:, :, :, None, :] - a[:, :, None, :, :]      # (b,nc,L,L,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    m = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    y_intra = jnp.einsum("bclm,bclmh,bcmhp->bclhp", g, m, dtx)

    # --- chunk states ------------------------------------------------------
    state_decay = jnp.exp(a_last[:, :, None, :] - a)     # (b,nc,L,h)
    S = jnp.einsum("bcln,bclh,bclhp->bchnp", Bf, state_decay, dtx)

    # --- inter-chunk scan over the tiny (h, n, p) state --------------------
    lam = jnp.exp(a_last)                                # (b,nc,h)

    def step(hprev, inputs):
        lam_c, S_c = inputs
        hnew = hprev * lam_c[..., None, None] + S_c
        return hnew, hprev

    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    _, hprevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(lam, 1, 0), jnp.moveaxis(S, 1, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 1)                  # (b,nc,h,n,p)

    y_inter = jnp.einsum("bcln,bclh,bchnp->bclhp",
                         Cf, jnp.exp(a), hprevs)

    y = (y_intra + y_inter).reshape(b, sp, h, p)[:, :s]
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] * \
            x.astype(jnp.float32).reshape(b, sp, h, p)[:, :s]
    return y.astype(x.dtype)


def ssd_decode_step(hstate: jnp.ndarray, x_t: jnp.ndarray, dt_t: jnp.ndarray,
                    A: jnp.ndarray, B_t: jnp.ndarray, C_t: jnp.ndarray,
                    D: jnp.ndarray | None = None):
    """Single-token recurrent step for serving.

    hstate: (B,H,N,P); x_t: (B,H,P); dt_t: (B,H); B_t/C_t: (B,N).
    Returns (new_state, y_t)."""
    dA = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32))
    dBx = jnp.einsum("bn,bhp->bhnp", B_t.astype(jnp.float32),
                     dt_t.astype(jnp.float32)[..., None]
                     * x_t.astype(jnp.float32))
    hnew = hstate * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", C_t.astype(jnp.float32), hnew)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, :, None] * x_t.astype(jnp.float32)
    return hnew, y.astype(x_t.dtype)
